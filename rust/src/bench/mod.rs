//! Shared benchmark harness: wall-clock timing, table/CSV reporting, and
//! the workload runners the paper-figure benches build on. (criterion is
//! not in the offline crate set; this module provides the equivalents the
//! repo needs, with deterministic workloads.)

use crate::config::ModelConfig;
use crate::edits::trace::{
    modified_fraction, sample_atomic, RevisionTrace, TraceConfig,
};
use crate::edits::{diff_tokens, Edit};
use crate::flops::dense_forward_flops;
use crate::incremental::{EngineOptions, IncrementalEngine};
use crate::model::ModelWeights;
use crate::util::{median, Rng};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Timing statistics over repeated runs.
#[derive(Clone, Debug)]
pub struct Timing {
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub min: Duration,
    pub max: Duration,
}

/// Time `f` with warmup; reports robust statistics.
pub fn time_it(warmup: usize, iters: usize, mut f: impl FnMut()) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    samples.sort();
    let sum: Duration = samples.iter().sum();
    Timing {
        iters,
        mean: sum / iters as u32,
        p50: samples[iters / 2],
        min: samples[0],
        max: samples[iters - 1],
    }
}

/// Markdown-ish table printer (fixed-width columns).
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n## {title}\n");
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let line = |cells: Vec<String>| {
        let mut s = String::from("|");
        for (i, c) in cells.iter().enumerate() {
            s += &format!(" {:<w$} |", c, w = widths[i]);
        }
        println!("{s}");
    };
    line(header.iter().map(|s| s.to_string()).collect());
    println!(
        "|{}|",
        widths
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("|")
    );
    for row in rows {
        line(row.clone());
    }
}

/// Append a bench's headline metrics to the consolidated JSON file named
/// by `VQT_BENCH_JSON` (the CI bench-smoke trajectory emitter — see
/// docs/BENCH_SCHEMA.md). The file is one top-level object keyed by bench
/// name; each bench read-modify-writes its own entry, so the benches can
/// run in any order and the union lands in one artifact. No-op when the
/// env var is unset. Metric-name convention: suffix `_wall_ns` for
/// wall-clock nanoseconds, `_flops` for ledger ops, `_ops` for op counts,
/// `_ratio` for dimensionless ratios.
pub fn emit_json(bench: &str, metrics: &[(&str, f64)]) {
    let Some(path) = std::env::var_os("VQT_BENCH_JSON") else {
        return;
    };
    emit_json_to(path.as_ref(), bench, metrics);
}

/// [`emit_json`] with an explicit target path (the env-var-free core —
/// also what the tests drive, so they never mutate the process
/// environment under the multithreaded test harness).
fn emit_json_to(path: &std::path::Path, bench: &str, metrics: &[(&str, f64)]) {
    // An absent file is the normal first-emitter case; an unparseable or
    // non-object one means earlier benches' metrics are about to be
    // discarded — warn rather than silently shipping a partial artifact.
    let mut root = match std::fs::read_to_string(path) {
        Err(_) => crate::util::Json::Obj(Default::default()),
        Ok(t) => match crate::util::Json::parse(&t) {
            Ok(j) => j,
            Err(e) => {
                eprintln!(
                    "warning: {} held invalid JSON ({e}); resetting it — previously emitted bench metrics are lost",
                    path.display()
                );
                crate::util::Json::Obj(Default::default())
            }
        },
    };
    if !matches!(root, crate::util::Json::Obj(_)) {
        eprintln!(
            "warning: {} did not hold a JSON object; resetting it — previously emitted bench metrics are lost",
            path.display()
        );
        root = crate::util::Json::Obj(Default::default());
    }
    let entry = crate::util::Json::obj(
        metrics
            .iter()
            .map(|&(k, v)| (k, crate::util::Json::num(v)))
            .collect(),
    );
    if let crate::util::Json::Obj(map) = &mut root {
        map.insert(bench.to_string(), entry);
    }
    if let Err(e) = std::fs::write(path, format!("{root}\n")) {
        eprintln!("(emit_json: could not write {}: {e})", path.display());
    } else {
        println!(
            "(emitted {} metrics for '{bench}' to {})",
            metrics.len(),
            path.display()
        );
    }
}

/// Environment-tunable workload size: `VQT_BENCH_PAIRS` (default mirrors
/// the paper's 500, scaled down to keep `cargo bench` under control; set
/// to 500 for the full protocol).
pub fn bench_pairs() -> usize {
    std::env::var("VQT_BENCH_PAIRS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(120)
}

/// The serving-model weights benches run against: the trained checkpoint
/// from `make train` when present, deterministic random init otherwise
/// (clearly labelled in output via the returned flag).
pub fn serving_weights(cfg: &ModelConfig, trained_name: &str) -> (Arc<ModelWeights>, bool) {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts")
        .join(trained_name);
    if path.exists() {
        if let Ok(w) = ModelWeights::load(&path, cfg) {
            return (Arc::new(w), true);
        }
    }
    (Arc::new(ModelWeights::random(cfg, 7)), false)
}

/// A revision-pair workload: consecutive revisions from synthetic traces
/// in the paper's length window protocol.
pub fn gen_pairs(cfg: &TraceConfig, n_pairs: usize, seed: u64) -> Vec<(Vec<u32>, Vec<u32>)> {
    let mut rng = Rng::new(seed);
    let mut pairs = Vec::with_capacity(n_pairs);
    // Several documents, several revisions each (mirrors "articles with a
    // long history of revisions").
    while pairs.len() < n_pairs {
        let revs = (n_pairs - pairs.len()).min(11).max(2);
        let trace = RevisionTrace::generate(cfg, revs, &mut rng);
        for (a, b) in trace.pairs() {
            if pairs.len() < n_pairs {
                pairs.push((a.clone(), b.clone()));
            }
        }
    }
    pairs
}

/// Result of one incremental measurement.
#[derive(Clone, Debug)]
pub struct Measured {
    /// Ops the incremental engine spent.
    pub incremental_flops: u64,
    /// Ops a dense from-scratch pass over the result would cost.
    pub dense_flops: u64,
    /// Fig-3 x-axis (offline) or normalized location (online).
    pub x: f64,
    pub defragged: bool,
}

impl Measured {
    pub fn speedup(&self) -> f64 {
        self.dense_flops as f64 / self.incremental_flops.max(1) as f64
    }
}

/// Offline protocol (Table 2 "Entire Revision", Fig. 3): the engine holds
/// revision A, a whole revision B arrives, the diff is applied
/// incrementally. Speedup = dense(B) / incremental ops.
pub fn measure_offline_pair(
    w: &Arc<ModelWeights>,
    opts: EngineOptions,
    a: &[u32],
    b: &[u32],
) -> Measured {
    let mut eng = IncrementalEngine::new(w.clone(), a, opts);
    eng.ledger = Default::default();
    let script = diff_tokens(a, b);
    let rep = eng.apply_revision(&script);
    Measured {
        incremental_flops: rep.flops,
        dense_flops: dense_forward_flops(&w.cfg, b.len()),
        x: modified_fraction(a, b),
        defragged: rep.defragged,
    }
}

/// Online protocol (Table 2 "Atomic", Fig. 4): sample one atomic edit from
/// the pair per the paper (§4), apply it to a warm engine.
pub fn measure_atomic(
    w: &Arc<ModelWeights>,
    opts: EngineOptions,
    a: &[u32],
    b: &[u32],
    window: Option<(f64, f64)>,
    rng: &mut Rng,
) -> Option<Measured> {
    let sample = sample_atomic(a, b, window, rng)?;
    if sample.base.len() >= w.cfg.max_seq {
        return None;
    }
    let mut eng = IncrementalEngine::new(w.clone(), &sample.base, opts);
    eng.ledger = Default::default();
    let rep = eng.apply_edit(sample.edit);
    Some(Measured {
        incremental_flops: rep.flops,
        dense_flops: dense_forward_flops(&w.cfg, eng.len()),
        x: sample.normalized_pos,
        defragged: rep.defragged,
    })
}

/// Baseline speedup of a from-scratch model vs OPT-mini from-scratch
/// (DistilOPT's "2×" row in Table 2 = depth ratio, computed honestly from
/// the FLOP formulas).
pub fn baseline_speedup(full: &ModelConfig, small: &ModelConfig, n: usize) -> f64 {
    dense_forward_flops(full, n) as f64 / dense_forward_flops(small, n) as f64
}

/// Median speedup across measurements.
pub fn median_speedup(ms: &[Measured]) -> f64 {
    median(&ms.iter().map(|m| m.speedup()).collect::<Vec<_>>())
}

/// Simple CSV dump for figure series.
pub fn write_csv(path: &str, header: &str, rows: &[(f64, f64)]) {
    use std::io::Write;
    let mut f = std::fs::File::create(path).expect("create csv");
    writeln!(f, "{header}").unwrap();
    for (x, y) in rows {
        writeln!(f, "{x},{y}").unwrap();
    }
    println!("(wrote {path}: {} points)", rows.len());
}

/// Edit-based variant of `Edit` application to a token vec, for workload
/// bookkeeping in benches.
pub fn apply(tokens: &[u32], e: Edit) -> Vec<u32> {
    crate::edits::apply_edits(tokens, &[e])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gen_pairs_count_and_window() {
        let cfg = TraceConfig::tiny();
        let pairs = gen_pairs(&cfg, 25, 1);
        assert_eq!(pairs.len(), 25);
        for (a, b) in &pairs {
            assert!(a.len() >= cfg.min_len && b.len() <= cfg.max_len);
        }
    }

    #[test]
    fn offline_measurement_speedup_positive() {
        let cfg = ModelConfig::vqt_tiny();
        let w = Arc::new(ModelWeights::random(&cfg, 3));
        let tcfg = TraceConfig::tiny();
        let pairs = gen_pairs(&tcfg, 3, 2);
        for (a, b) in &pairs {
            let m = measure_offline_pair(&w, EngineOptions::default(), a, b);
            assert!(m.speedup() > 0.5, "speedup {}", m.speedup());
            assert!(m.x > 0.0);
        }
    }

    #[test]
    fn atomic_measurement() {
        let cfg = ModelConfig::vqt_tiny();
        let w = Arc::new(ModelWeights::random(&cfg, 4));
        let tcfg = TraceConfig::tiny();
        let pairs = gen_pairs(&tcfg, 6, 5);
        let mut rng = Rng::new(6);
        let mut got = 0;
        for (a, b) in &pairs {
            if let Some(m) = measure_atomic(&w, EngineOptions::default(), a, b, None, &mut rng) {
                assert!(m.speedup() > 1.0, "atomic speedup {}", m.speedup());
                got += 1;
            }
        }
        assert!(got >= 4);
    }

    #[test]
    fn timing_smoke() {
        let t = time_it(1, 5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(t.iters, 5);
        assert!(t.min <= t.p50 && t.p50 <= t.max);
    }

    #[test]
    fn emit_json_merges_across_benches() {
        let path = std::env::temp_dir().join(format!("vqt_bench_{}.json", std::process::id()));
        let _ = std::fs::remove_file(&path);
        // Drive the env-var-free core directly: mutating the process env
        // (set_var) races concurrent getenv calls from parallel tests.
        emit_json_to(&path, "bench_a", &[("x_wall_ns", 123.0), ("y_flops", 4.0)]);
        emit_json_to(&path, "bench_b", &[("z_ratio", 2.5)]);
        // Re-emitting a bench replaces its entry, keeps the others.
        emit_json_to(&path, "bench_a", &[("x_wall_ns", 456.0)]);
        let j = crate::util::Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(j.get("bench_a").get("x_wall_ns").as_f64(), Some(456.0));
        assert!(j.get("bench_a").get("y_flops").as_f64().is_none());
        assert_eq!(j.get("bench_b").get("z_ratio").as_f64(), Some(2.5));
        let _ = std::fs::remove_file(&path);
    }
}
