//! Configuration system: model hyper-parameters, serving options, bench
//! parameters. Everything loads from JSON files (see `configs/` at the repo
//! root) or from the built-in presets used by tests and benches.

use crate::util::Json;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Which attention normalization the model uses.
///
/// The paper replaces softmax with an element-wise non-linearity (GELU) so
/// that incremental column corrections are exact (§3, eq. 1). `Softmax` is
/// kept for the OPT-style dense baseline.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AttentionKind {
    Softmax,
    GeluElementwise,
}

impl AttentionKind {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "softmax" => Ok(AttentionKind::Softmax),
            "gelu" => Ok(AttentionKind::GeluElementwise),
            other => bail!("unknown attention kind '{other}' (want softmax|gelu)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            AttentionKind::Softmax => "softmax",
            AttentionKind::GeluElementwise => "gelu",
        }
    }
}

/// Transformer + VQ hyper-parameters.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    /// Token vocabulary (byte-level: 256 + PAD).
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    /// Attention heads.
    pub n_heads: usize,
    pub d_ff: usize,
    /// Maximum document length in tokens.
    pub max_seq: usize,
    /// Positional-embedding pool size (§3.3): `gap_factor × max_seq`.
    pub pos_pool: usize,
    /// Multi-head VQ heads (0 disables VQ ⇒ plain baseline model).
    pub vq_heads: usize,
    /// Codes per VQ head (paper: 64).
    pub vq_codes: usize,
    pub attention: AttentionKind,
    /// Classifier classes (sentiment: 2).
    pub n_classes: usize,
    pub ln_eps: f32,
}

impl ModelConfig {
    /// The VQT-mini preset — the trained/served model (substitute for
    /// VQ-OPT-125M at laptop scale; see docs/ARCHITECTURE.md).
    pub fn vqt_mini() -> ModelConfig {
        ModelConfig {
            vocab_size: 257, // 256 bytes + PAD
            d_model: 128,
            n_layers: 4,
            n_heads: 4,
            d_ff: 512,
            max_seq: 512,
            pos_pool: 512 * 8,
            vq_heads: 2,
            vq_codes: 64,
            attention: AttentionKind::GeluElementwise,
            n_classes: 2,
            ln_eps: 1e-5,
        }
    }

    /// Tiny preset for fast unit/property tests.
    pub fn vqt_tiny() -> ModelConfig {
        ModelConfig {
            vocab_size: 64,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            d_ff: 64,
            max_seq: 64,
            pos_pool: 64 * 8,
            vq_heads: 2,
            vq_codes: 16,
            attention: AttentionKind::GeluElementwise,
            n_classes: 2,
            ln_eps: 1e-5,
        }
    }

    /// OPT-125M dimensions, used for *analytic* FLOP reporting at paper
    /// scale (never executed densely on this host).
    pub fn opt_125m_scale() -> ModelConfig {
        ModelConfig {
            vocab_size: 50272,
            d_model: 768,
            n_layers: 12,
            n_heads: 12,
            d_ff: 3072,
            max_seq: 2048,
            pos_pool: 2048 * 8,
            vq_heads: 2,
            vq_codes: 64,
            attention: AttentionKind::GeluElementwise,
            n_classes: 2,
            ln_eps: 1e-5,
        }
    }

    /// Head dimension.
    pub fn d_head(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Per-VQ-head chunk width.
    pub fn vq_dim(&self) -> usize {
        assert!(self.vq_heads > 0, "vq_dim on a non-VQ model");
        self.d_model / self.vq_heads
    }

    /// Approximate parameter count (reporting only).
    pub fn param_count(&self) -> usize {
        let d = self.d_model;
        let mut p = self.vocab_size * d + self.pos_pool * d;
        p += self.n_layers
            * (4 * d * d + 4 * d          // qkv+mix weights and biases
                + 2 * d * self.d_ff + self.d_ff + d // ffn
                + 4 * d                   // ln params
                + if self.vq_heads > 0 { self.vq_codes * d } else { 0 });
        p += 2 * d; // final LN
        p += d * self.n_classes + self.n_classes;
        p
    }

    pub fn validate(&self) -> Result<()> {
        if self.d_model % self.n_heads != 0 {
            bail!("d_model {} not divisible by n_heads {}", self.d_model, self.n_heads);
        }
        if self.vq_heads > 0 && self.d_model % self.vq_heads != 0 {
            bail!("d_model {} not divisible by vq_heads {}", self.d_model, self.vq_heads);
        }
        if self.pos_pool < self.max_seq {
            bail!("pos_pool {} < max_seq {}", self.pos_pool, self.max_seq);
        }
        if self.vocab_size == 0 || self.n_layers == 0 || self.n_classes == 0 {
            bail!("zero-sized model dimension");
        }
        Ok(())
    }

    pub fn from_json(j: &Json) -> Result<ModelConfig> {
        let base = match j.get("preset").as_str() {
            Some("vqt_mini") | None => ModelConfig::vqt_mini(),
            Some("vqt_tiny") => ModelConfig::vqt_tiny(),
            Some("opt_125m_scale") => ModelConfig::opt_125m_scale(),
            Some(p) => bail!("unknown preset '{p}'"),
        };
        let u = |key: &str, dflt: usize| -> usize { j.get(key).as_usize().unwrap_or(dflt) };
        let mut cfg = ModelConfig {
            vocab_size: u("vocab_size", base.vocab_size),
            d_model: u("d_model", base.d_model),
            n_layers: u("n_layers", base.n_layers),
            n_heads: u("n_heads", base.n_heads),
            d_ff: u("d_ff", base.d_ff),
            max_seq: u("max_seq", base.max_seq),
            pos_pool: u("pos_pool", base.pos_pool),
            vq_heads: u("vq_heads", base.vq_heads),
            vq_codes: u("vq_codes", base.vq_codes),
            attention: base.attention,
            n_classes: u("n_classes", base.n_classes),
            ln_eps: j.get("ln_eps").as_f64().unwrap_or(base.ln_eps as f64) as f32,
        };
        if let Some(s) = j.get("attention").as_str() {
            cfg.attention = AttentionKind::parse(s)?;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("vocab_size", Json::num(self.vocab_size as f64)),
            ("d_model", Json::num(self.d_model as f64)),
            ("n_layers", Json::num(self.n_layers as f64)),
            ("n_heads", Json::num(self.n_heads as f64)),
            ("d_ff", Json::num(self.d_ff as f64)),
            ("max_seq", Json::num(self.max_seq as f64)),
            ("pos_pool", Json::num(self.pos_pool as f64)),
            ("vq_heads", Json::num(self.vq_heads as f64)),
            ("vq_codes", Json::num(self.vq_codes as f64)),
            ("attention", Json::str(self.attention.name())),
            ("n_classes", Json::num(self.n_classes as f64)),
            ("ln_eps", Json::num(self.ln_eps as f64)),
        ])
    }
}

/// Serving options for the coordinator.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// TCP bind address for the JSON server.
    pub bind: String,
    /// Worker shards executing inference. Sessions are hash-routed to a
    /// fixed shard so each engine keeps single-threaded ownership (no
    /// locks on the hot path); throughput scales with this up to the core
    /// count. `queue_capacity` and `max_sessions` are pool-wide and split
    /// evenly across shards. Clamped to ≥ 1.
    pub workers: usize,
    /// Max requests batched together (offline batch path), per shard.
    pub max_batch: usize,
    /// Batching deadline: flush a partial batch after this many ms.
    /// Superseded by `batch_window_us` when that is non-zero.
    pub batch_deadline_ms: u64,
    /// Queue-drain window in µs for the size-or-timeout batcher: after the
    /// first job arrives, the shard keeps draining its queue until
    /// `max_batch` jobs are collected or this window elapses, then
    /// executes the batch (cross-session edit work pooled into stacked
    /// GEMMs). 0 ⇒ fall back to the coarser `batch_deadline_ms`.
    pub batch_window_us: u64,
    /// Cap on rows stacked into one pooled cross-session block-tail GEMM.
    /// Bounds the GEMM working set — the `rows × d_ff` FFN intermediate is
    /// the largest per-chunk buffer; the gather/scatter staging itself
    /// scales with the wave's total changed rows, which `max_batch` (the
    /// sessions per drain) bounds. 0 disables the batched execution path
    /// entirely (every request runs the classic per-session path).
    pub max_batch_rows: usize,
    /// Pool-wide queue capacity before backpressure rejects new requests
    /// (each shard gets `queue_capacity / workers`, at least 1).
    pub queue_capacity: usize,
    /// Periodically verify incremental state against a dense recompute
    /// every N edits (0 disables) — failure-detection knob.
    pub verify_every: usize,
    /// Pool-wide max live sessions — resident *plus* suspended — before
    /// the globally least-recently-used session is dropped entirely (each
    /// shard caps at `max_sessions / workers`, at least 1).
    pub max_sessions: usize,
    /// Pool-wide cap on sessions resident in RAM. Beyond it, cold sessions
    /// are suspended: snapshotted to `spill_dir` (or dropped when no spill
    /// dir is configured) and transparently resumed on their next request.
    /// 0 ⇒ same as `max_sessions` (count pressure never suspends).
    pub max_resident_sessions: usize,
    /// Pool-wide budget for resident session state, in MiB, measured by
    /// byte-level accounting of each engine's row stores and bookkeeping.
    /// LRU sessions are suspended until the measured total fits. 0 ⇒
    /// unlimited.
    pub memory_budget_mb: usize,
    /// Directory session snapshots spill to (the coordinator creates a
    /// per-instance `instance-<pid>` subdirectory inside it, so multiple
    /// server instances can share the path). Empty ⇒ spilling disabled:
    /// over-cap sessions are dropped (the pre-lifecycle behavior).
    pub spill_dir: String,
    /// Process-global codebook-product cache budget, in MiB. Each
    /// layer's `decode(code)·w_mix` product is a pure function of
    /// `(layer, code)`, so it is cached once and shared by every session
    /// on every shard; entries beyond the budget are evicted LRU. 0 ⇒
    /// cache disabled (the classic per-row decode→mix path).
    pub code_cache_mb: usize,
    /// Kernel backend for the dense hot-path cores:
    /// `"auto"` (runtime feature detection picks AVX2/NEON when present),
    /// `"scalar"` (force the portable reference core), or `"simd"`
    /// (prefer the explicit-SIMD core; falls back to scalar on CPUs
    /// without AVX2/NEON). Every backend is bit-identical — this knob
    /// trades nothing but speed. The `VQT_KERNEL_BACKEND` env var
    /// overrides an `"auto"` config (see `tensor::set_kernel_backend`).
    pub kernel_backend: String,
    /// Event-loop IO threads for the async front end (Linux). Thread 0
    /// also owns the listener; accepted connections are spread round-robin
    /// across all IO threads. Clamped to ≥ 1. The blocking fallback server
    /// ignores this knob (it spawns one thread per connection).
    pub io_threads: usize,
    /// Admission control: maximum concurrently open client connections.
    /// A connection past the cap is answered with one typed `busy` line
    /// and closed immediately (counted in `shed_connections`). 0 ⇒
    /// unlimited (tests / trusted front ends).
    pub max_connections: usize,
    /// Per-connection backpressure: maximum requests in flight (submitted
    /// to a shard, reply not yet flushed) before the event loop stops
    /// *reading* from that connection. Reads resume as replies drain, so a
    /// pipelining client is throttled instead of buffered unboundedly.
    /// Clamped to ≥ 1.
    pub max_inflight_per_conn: usize,
    /// Directory `checkpoint`/`restore` snapshot paths are confined to.
    /// Clients name bare files (no separators, no `..`, not absolute);
    /// the coordinator joins them onto this directory. Empty ⇒ the
    /// checkpoint/restore verbs are disabled (secure default: a client
    /// must not be able to read or write server paths unless an operator
    /// opted in).
    pub checkpoint_dir: String,
    /// Completed request traces retained per shard ring (plus one ring in
    /// the async front end) for the `trace` verb. Non-zero turns span
    /// collection on for *every* request; 0 ⇒ only requests carrying
    /// `"trace":true` are traced, and nothing is retained in the rings.
    pub trace_buffer: usize,
    /// Slow-request sampling threshold in microseconds: any traced
    /// request whose end-to-end time (enqueue → reply dispatch) meets it
    /// logs its full span breakdown at WARN and counts in
    /// `slow_requests`. Non-zero also turns span collection on for every
    /// request. 0 ⇒ disabled.
    pub slow_request_us: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            bind: "127.0.0.1:7478".to_string(),
            workers: 1,
            max_batch: 8,
            batch_deadline_ms: 5,
            batch_window_us: 0,
            max_batch_rows: 64,
            queue_capacity: 256,
            verify_every: 0,
            max_sessions: 64,
            max_resident_sessions: 0,
            memory_budget_mb: 0,
            spill_dir: String::new(),
            code_cache_mb: 0,
            kernel_backend: "auto".to_string(),
            io_threads: 2,
            max_connections: 0,
            max_inflight_per_conn: 32,
            checkpoint_dir: String::new(),
            trace_buffer: 0,
            slow_request_us: 0,
        }
    }
}

impl ServeConfig {
    pub fn from_json(j: &Json) -> Result<ServeConfig> {
        let d = ServeConfig::default();
        Ok(ServeConfig {
            bind: j.get("bind").as_str().unwrap_or(&d.bind).to_string(),
            workers: j.get("workers").as_usize().unwrap_or(d.workers).max(1),
            max_batch: j.get("max_batch").as_usize().unwrap_or(d.max_batch),
            batch_deadline_ms: j
                .get("batch_deadline_ms")
                .as_usize()
                .unwrap_or(d.batch_deadline_ms as usize) as u64,
            batch_window_us: j
                .get("batch_window_us")
                .as_usize()
                .unwrap_or(d.batch_window_us as usize) as u64,
            max_batch_rows: j
                .get("max_batch_rows")
                .as_usize()
                .unwrap_or(d.max_batch_rows),
            queue_capacity: j.get("queue_capacity").as_usize().unwrap_or(d.queue_capacity),
            verify_every: j.get("verify_every").as_usize().unwrap_or(d.verify_every),
            max_sessions: j.get("max_sessions").as_usize().unwrap_or(d.max_sessions),
            max_resident_sessions: j
                .get("max_resident_sessions")
                .as_usize()
                .unwrap_or(d.max_resident_sessions),
            memory_budget_mb: j
                .get("memory_budget_mb")
                .as_usize()
                .unwrap_or(d.memory_budget_mb),
            spill_dir: j.get("spill_dir").as_str().unwrap_or(&d.spill_dir).to_string(),
            code_cache_mb: j
                .get("code_cache_mb")
                .as_usize()
                .unwrap_or(d.code_cache_mb),
            kernel_backend: {
                let s = j
                    .get("kernel_backend")
                    .as_str()
                    .unwrap_or(&d.kernel_backend)
                    .to_string();
                // Reject typos at config-load time, not at first matmul.
                crate::tensor::KernelBackend::parse(&s)
                    .map_err(anyhow::Error::msg)
                    .context("serve.kernel_backend")?;
                s
            },
            io_threads: j.get("io_threads").as_usize().unwrap_or(d.io_threads).max(1),
            max_connections: j
                .get("max_connections")
                .as_usize()
                .unwrap_or(d.max_connections),
            max_inflight_per_conn: j
                .get("max_inflight_per_conn")
                .as_usize()
                .unwrap_or(d.max_inflight_per_conn)
                .max(1),
            checkpoint_dir: j
                .get("checkpoint_dir")
                .as_str()
                .unwrap_or(&d.checkpoint_dir)
                .to_string(),
            trace_buffer: j.get("trace_buffer").as_usize().unwrap_or(d.trace_buffer),
            slow_request_us: j
                .get("slow_request_us")
                .as_usize()
                .unwrap_or(d.slow_request_us as usize) as u64,
        })
    }
}

/// Load a JSON config file into (ModelConfig, ServeConfig).
pub fn load_config_file(path: impl AsRef<Path>) -> Result<(ModelConfig, ServeConfig)> {
    let text = std::fs::read_to_string(path.as_ref())
        .with_context(|| format!("reading config {}", path.as_ref().display()))?;
    let j = Json::parse(&text).context("parsing config JSON")?;
    let model = ModelConfig::from_json(j.get("model"))?;
    let serve = ServeConfig::from_json(j.get("serve"))?;
    Ok((model, serve))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_validate() {
        ModelConfig::vqt_mini().validate().unwrap();
        ModelConfig::vqt_tiny().validate().unwrap();
        ModelConfig::opt_125m_scale().validate().unwrap();
    }

    #[test]
    fn opt_scale_param_count_near_125m() {
        let p = ModelConfig::opt_125m_scale().param_count();
        // OPT-125M is ~125M; our pos pool is larger (8× gap factor).
        assert!(p > 100_000_000 && p < 200_000_000, "params {p}");
    }

    #[test]
    fn json_roundtrip() {
        let cfg = ModelConfig::vqt_mini();
        let j = cfg.to_json();
        let back = ModelConfig::from_json(&j).unwrap();
        assert_eq!(back, cfg);
    }

    #[test]
    fn json_overrides() {
        let j = Json::parse(r#"{"preset": "vqt_tiny", "n_layers": 3, "attention": "softmax"}"#)
            .unwrap();
        let cfg = ModelConfig::from_json(&j).unwrap();
        assert_eq!(cfg.n_layers, 3);
        assert_eq!(cfg.attention, AttentionKind::Softmax);
        assert_eq!(cfg.d_model, ModelConfig::vqt_tiny().d_model);
    }

    #[test]
    fn invalid_configs_rejected() {
        let mut cfg = ModelConfig::vqt_tiny();
        cfg.n_heads = 5; // 32 % 5 != 0
        assert!(cfg.validate().is_err());
        let mut cfg = ModelConfig::vqt_tiny();
        cfg.pos_pool = 8;
        assert!(cfg.validate().is_err());
    }
}

impl ModelConfig {
    /// The Table-1 model variants at laptop scale — mirrors
    /// `python/compile/model.py::table1_cfg`.
    pub fn table1(variant: &str) -> anyhow::Result<ModelConfig> {
        let base = ModelConfig {
            vocab_size: 257,
            d_model: 64,
            n_layers: 2,
            n_heads: 4,
            d_ff: 256,
            max_seq: 128,
            pos_pool: 128 * 8,
            vq_heads: 0,
            vq_codes: 0,
            attention: AttentionKind::Softmax,
            n_classes: 2,
            ln_eps: 1e-5,
        };
        let cfg = match variant {
            "opt" => base,
            "distil" => ModelConfig { n_layers: 1, ..base },
            "vq_h2" => ModelConfig {
                vq_heads: 2,
                vq_codes: 64,
                attention: AttentionKind::GeluElementwise,
                ..base
            },
            "vq_h4" => ModelConfig {
                vq_heads: 4,
                vq_codes: 64,
                attention: AttentionKind::GeluElementwise,
                ..base
            },
            other => anyhow::bail!("unknown table1 variant '{other}'"),
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// vqt_mini with 4 VQ heads (the serving-scale h=4 row of Table 2).
    pub fn vqt_mini_h4() -> ModelConfig {
        ModelConfig {
            vq_heads: 4,
            ..ModelConfig::vqt_mini()
        }
    }
}

#[cfg(test)]
mod file_tests {
    use super::*;

    #[test]
    fn ships_a_valid_example_config() {
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs/serve.json");
        let (model, serve) = load_config_file(path).unwrap();
        assert_eq!(model, ModelConfig::vqt_mini());
        assert_eq!(serve.verify_every, 256);
        assert_eq!(serve.bind, "127.0.0.1:7478");
        // The shipped config serves from a 4-shard pool.
        assert_eq!(serve.workers, 4);
        // Cross-session batching: short drain window, pooled GEMMs capped.
        assert_eq!(serve.batch_window_us, 200);
        assert_eq!(serve.max_batch_rows, 128);
        // Session-lifecycle knobs: spill cold sessions under pressure.
        assert_eq!(serve.max_resident_sessions, 32);
        assert_eq!(serve.memory_budget_mb, 512);
        assert_eq!(serve.spill_dir, "/tmp/vqt-sessions");
        // Cross-session codebook-product cache on in the shipped config.
        assert_eq!(serve.code_cache_mb, 64);
        // Kernel backend: runtime feature detection by default.
        assert_eq!(serve.kernel_backend, "auto");
        // Async front end: a few IO threads, admission control on.
        assert_eq!(serve.io_threads, 2);
        assert_eq!(serve.max_connections, 4096);
        assert_eq!(serve.max_inflight_per_conn, 32);
        // Snapshot verbs confined to an operator-chosen directory.
        assert_eq!(serve.checkpoint_dir, "/tmp/vqt-checkpoints");
        // Observability: trace ring on, slow-request sampling at 50ms.
        assert_eq!(serve.trace_buffer, 64);
        assert_eq!(serve.slow_request_us, 50_000);
    }

    #[test]
    fn trace_knobs_default_off_and_override() {
        let j = Json::parse(r#"{}"#).unwrap();
        let sc = ServeConfig::from_json(&j).unwrap();
        assert_eq!(sc.trace_buffer, 0, "span collection strictly opt-in");
        assert_eq!(sc.slow_request_us, 0, "slow sampling strictly opt-in");
        let j = Json::parse(r#"{"trace_buffer": 32, "slow_request_us": 1500}"#).unwrap();
        let sc = ServeConfig::from_json(&j).unwrap();
        assert_eq!(sc.trace_buffer, 32);
        assert_eq!(sc.slow_request_us, 1500);
    }

    #[test]
    fn kernel_backend_defaults_auto_validates_and_rejects_typos() {
        let j = Json::parse(r#"{}"#).unwrap();
        assert_eq!(ServeConfig::from_json(&j).unwrap().kernel_backend, "auto");
        let j = Json::parse(r#"{"kernel_backend": "scalar"}"#).unwrap();
        assert_eq!(ServeConfig::from_json(&j).unwrap().kernel_backend, "scalar");
        let j = Json::parse(r#"{"kernel_backend": "simd"}"#).unwrap();
        assert_eq!(ServeConfig::from_json(&j).unwrap().kernel_backend, "simd");
        let j = Json::parse(r#"{"kernel_backend": "avx512"}"#).unwrap();
        let err = format!("{:#}", ServeConfig::from_json(&j).unwrap_err());
        assert!(err.contains("kernel_backend"), "{err}");
        assert!(err.contains("avx512"), "{err}");
    }

    #[test]
    fn lifecycle_knobs_default_off() {
        let j = Json::parse(r#"{}"#).unwrap();
        let sc = ServeConfig::from_json(&j).unwrap();
        assert_eq!(sc.max_resident_sessions, 0);
        assert_eq!(sc.memory_budget_mb, 0);
        assert!(sc.spill_dir.is_empty());
    }

    #[test]
    fn code_cache_defaults_off_and_overrides() {
        let j = Json::parse(r#"{}"#).unwrap();
        let sc = ServeConfig::from_json(&j).unwrap();
        assert_eq!(sc.code_cache_mb, 0, "cache strictly opt-in");
        let j = Json::parse(r#"{"code_cache_mb": 16}"#).unwrap();
        assert_eq!(ServeConfig::from_json(&j).unwrap().code_cache_mb, 16);
    }

    #[test]
    fn batching_knob_defaults_and_overrides() {
        let j = Json::parse(r#"{}"#).unwrap();
        let sc = ServeConfig::from_json(&j).unwrap();
        // Batched execution on by default; window falls back to the ms
        // deadline until explicitly set.
        assert_eq!(sc.max_batch_rows, 64);
        assert_eq!(sc.batch_window_us, 0);
        let j = Json::parse(r#"{"batch_window_us": 250, "max_batch_rows": 0}"#).unwrap();
        let sc = ServeConfig::from_json(&j).unwrap();
        assert_eq!(sc.batch_window_us, 250);
        assert_eq!(sc.max_batch_rows, 0, "0 disables the batched path");
    }

    #[test]
    fn zero_workers_clamped_to_one() {
        let j = Json::parse(r#"{"workers": 0}"#).unwrap();
        assert_eq!(ServeConfig::from_json(&j).unwrap().workers, 1);
    }

    #[test]
    fn frontend_knob_defaults_and_clamps() {
        let j = Json::parse(r#"{}"#).unwrap();
        let sc = ServeConfig::from_json(&j).unwrap();
        assert_eq!(sc.io_threads, 2);
        assert_eq!(sc.max_connections, 0, "unlimited unless configured");
        assert_eq!(sc.max_inflight_per_conn, 32);
        assert!(sc.checkpoint_dir.is_empty(), "snapshot verbs off by default");
        // Degenerate values are clamped, not served.
        let j = Json::parse(r#"{"io_threads": 0, "max_inflight_per_conn": 0}"#).unwrap();
        let sc = ServeConfig::from_json(&j).unwrap();
        assert_eq!(sc.io_threads, 1);
        assert_eq!(sc.max_inflight_per_conn, 1);
        let j = Json::parse(
            r#"{"max_connections": 128, "checkpoint_dir": "/srv/ckpt", "io_threads": 4}"#,
        )
        .unwrap();
        let sc = ServeConfig::from_json(&j).unwrap();
        assert_eq!(sc.max_connections, 128);
        assert_eq!(sc.checkpoint_dir, "/srv/ckpt");
        assert_eq!(sc.io_threads, 4);
    }

    #[test]
    fn table1_variants_match_python() {
        // Mirrors python/compile/model.py::table1_cfg.
        let opt = ModelConfig::table1("opt").unwrap();
        assert_eq!((opt.d_model, opt.n_layers, opt.vq_heads), (64, 2, 0));
        assert_eq!(opt.attention, AttentionKind::Softmax);
        let h4 = ModelConfig::table1("vq_h4").unwrap();
        assert_eq!((h4.vq_heads, h4.vq_codes), (4, 64));
        assert_eq!(h4.attention, AttentionKind::GeluElementwise);
        assert!(ModelConfig::table1("bogus").is_err());
    }

    #[test]
    fn mini_h4_divides_heads() {
        let cfg = ModelConfig::vqt_mini_h4();
        cfg.validate().unwrap();
        assert_eq!(cfg.n_heads % cfg.vq_heads, 0);
    }

    #[test]
    fn missing_config_file_errors() {
        assert!(load_config_file("/nonexistent/zzz.json").is_err());
    }
}
