//! Synthetic revision-trace generator — the stand-in for the paper's
//! scraped Wikipedia edit histories (docs/ARCHITECTURE.md).
//!
//! The paper's evaluation needs, per Table 2 / Figs. 3–4:
//! - pairs of consecutive revisions of long documents (1536–2048 tokens in
//!   the paper; length window configurable here),
//! - a heavy-tailed mix of small and large revisions (fraction of modified
//!   tokens spanning ~0.1 % … 50 %, the x-axis of Fig. 3),
//! - an "atomic edit" protocol: pick a random modified location within a
//!   pair, apply all changes before it, and process just that one edit
//!   (Fig. 4's x-axis is the edit's normalized position).

use super::diff::{apply_edits, diff_tokens};
use super::Edit;
use crate::util::Rng;

/// Generator parameters.
#[derive(Clone, Debug)]
pub struct TraceConfig {
    /// Token vocabulary (tokens are drawn Zipf-like so documents have the
    /// self-similarity real text does).
    pub vocab: usize,
    /// Document length window (inclusive); revisions stay within it.
    pub min_len: usize,
    pub max_len: usize,
    /// Mean number of edit *spans* per revision (heavy-tailed).
    pub spans_mean: f64,
    /// Mean tokens per span (heavy-tailed).
    pub span_len_mean: f64,
}

impl TraceConfig {
    /// Mini-scale default mirroring the paper's protocol shape: the paper
    /// used 1536–2048-token Wikipedia revisions; we default to a 384–512
    /// window that the VQT-mini config can hold. Span statistics are
    /// calibrated so the fraction-modified distribution concentrates around
    /// 0.5–3 % with a heavy tail — the regime Wikipedia edits live in
    /// (most revisions touch a handful of tokens; a few rewrite sections).
    pub fn mini() -> TraceConfig {
        TraceConfig {
            vocab: 256,
            min_len: 384,
            max_len: 512,
            spans_mean: 1.4,
            span_len_mean: 3.5,
        }
    }

    /// Tiny config for unit tests.
    pub fn tiny() -> TraceConfig {
        TraceConfig {
            vocab: 50,
            min_len: 24,
            max_len: 48,
            spans_mean: 1.5,
            span_len_mean: 3.0,
        }
    }
}

/// Draw a token with a Zipf-ish rank-frequency profile.
fn sample_token(cfg: &TraceConfig, rng: &mut Rng) -> u32 {
    // Mixture: 70 % from the top ~10 % of the vocab, 30 % uniform.
    if rng.chance(0.7) {
        let top = (cfg.vocab / 10).max(1);
        rng.below(top) as u32
    } else {
        rng.below(cfg.vocab) as u32
    }
}

/// Generate an initial document within the length window.
pub fn generate_document(cfg: &TraceConfig, rng: &mut Rng) -> Vec<u32> {
    let n = rng.range(cfg.min_len, cfg.max_len);
    (0..n).map(|_| sample_token(cfg, rng)).collect()
}

/// Mutate a document into its next revision. Returns the new revision.
pub fn next_revision(cfg: &TraceConfig, doc: &[u32], rng: &mut Rng) -> Vec<u32> {
    let mut v = doc.to_vec();
    let spans = rng.heavy_count(cfg.spans_mean).min(32);
    for _ in 0..spans {
        if v.is_empty() {
            break;
        }
        let span = rng.heavy_count(cfg.span_len_mean).min(v.len() / 2 + 1);
        let at = rng.below(v.len());
        match rng.below(3) {
            0 => {
                // Replace a span.
                for i in at..(at + span).min(v.len()) {
                    v[i] = sample_token(cfg, rng);
                }
            }
            1 => {
                // Insert a span (respect max_len).
                let room = cfg.max_len.saturating_sub(v.len());
                for i in 0..span.min(room) {
                    v.insert(at + i, sample_token(cfg, rng));
                }
            }
            _ => {
                // Delete a span (respect min_len).
                let room = v.len().saturating_sub(cfg.min_len);
                let k = span.min(room).min(v.len() - at);
                for _ in 0..k {
                    v.remove(at);
                }
            }
        }
    }
    // Guarantee at least one modification so every pair is a real revision.
    if v == doc {
        let at = rng.below(v.len());
        let mut t = sample_token(cfg, rng);
        while t == v[at] {
            t = sample_token(cfg, rng);
        }
        v[at] = t;
    }
    v
}

/// A document's revision history.
#[derive(Clone, Debug)]
pub struct RevisionTrace {
    pub revisions: Vec<Vec<u32>>,
}

impl RevisionTrace {
    /// Generate a history of `n_revisions` (≥ 2) revisions.
    pub fn generate(cfg: &TraceConfig, n_revisions: usize, rng: &mut Rng) -> RevisionTrace {
        assert!(n_revisions >= 2);
        let mut revisions = Vec::with_capacity(n_revisions);
        revisions.push(generate_document(cfg, rng));
        for _ in 1..n_revisions {
            let next = next_revision(cfg, revisions.last().unwrap(), rng);
            revisions.push(next);
        }
        RevisionTrace { revisions }
    }

    /// Consecutive revision pairs.
    pub fn pairs(&self) -> impl Iterator<Item = (&Vec<u32>, &Vec<u32>)> {
        self.revisions.windows(2).map(|w| (&w[0], &w[1]))
    }
}

/// An atomic-edit sample drawn from a revision pair (paper §4, Fig. 4
/// protocol): `base` is the old revision with all changes *before* the
/// sampled one already applied; `edit` is the single change to process;
/// `normalized_pos` is its location divided by the document length.
#[derive(Clone, Debug)]
pub struct AtomicSample {
    pub base: Vec<u32>,
    pub edit: Edit,
    pub normalized_pos: f64,
}

/// Sample one atomic edit from the diff of a revision pair. Returns `None`
/// if the revisions are identical. `location_window` restricts the
/// normalized edit location (e.g. `Some((0.0, 0.05))` for Table 2's
/// "first 5 %" protocol).
pub fn sample_atomic(
    old: &[u32],
    new: &[u32],
    location_window: Option<(f64, f64)>,
    rng: &mut Rng,
) -> Option<AtomicSample> {
    let script = diff_tokens(old, new);
    if script.is_empty() {
        return None;
    }
    // Candidate indices honouring the location window.
    let candidates: Vec<usize> = (0..script.len())
        .filter(|&i| match location_window {
            None => true,
            Some((lo, hi)) => {
                let pos = script[i].at() as f64 / old.len().max(1) as f64;
                pos >= lo && pos <= hi
            }
        })
        .collect();
    if candidates.is_empty() {
        return None;
    }
    let pick = candidates[rng.below(candidates.len())];
    let base = apply_edits(old, &script[..pick]);
    let edit = script[pick];
    let normalized_pos = edit.at() as f64 / base.len().max(1) as f64;
    Some(AtomicSample {
        base,
        edit,
        normalized_pos,
    })
}

/// Fraction of modified tokens between two revisions — Fig. 3's x-axis
/// (edit distance over mean length).
pub fn modified_fraction(old: &[u32], new: &[u32]) -> f64 {
    let d = super::diff::edit_distance(old, new) as f64;
    let denom = (old.len() + new.len()) as f64 / 2.0;
    (d / denom.max(1.0)).min(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn documents_in_window() {
        let cfg = TraceConfig::tiny();
        let mut r = Rng::new(1);
        for _ in 0..50 {
            let d = generate_document(&cfg, &mut r);
            assert!(d.len() >= cfg.min_len && d.len() <= cfg.max_len);
            assert!(d.iter().all(|&t| (t as usize) < cfg.vocab));
        }
    }

    #[test]
    fn revisions_stay_in_window_and_differ() {
        let cfg = TraceConfig::tiny();
        let mut r = Rng::new(2);
        let trace = RevisionTrace::generate(&cfg, 20, &mut r);
        assert_eq!(trace.revisions.len(), 20);
        for (a, b) in trace.pairs() {
            assert!(b.len() >= cfg.min_len && b.len() <= cfg.max_len);
            assert_ne!(a, b, "every revision must modify something");
        }
    }

    #[test]
    fn modified_fraction_spans_a_range() {
        // The generator must produce both small and large revisions so the
        // Fig. 3 x-axis is covered.
        let cfg = TraceConfig::tiny();
        let mut r = Rng::new(3);
        let trace = RevisionTrace::generate(&cfg, 120, &mut r);
        let fracs: Vec<f64> = trace.pairs().map(|(a, b)| modified_fraction(a, b)).collect();
        let min = fracs.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = fracs.iter().cloned().fold(0.0, f64::max);
        assert!(min < 0.08, "need small revisions, min {min}");
        assert!(max > 0.15, "need large revisions, max {max}");
    }

    #[test]
    fn atomic_sample_is_consistent() {
        let cfg = TraceConfig::tiny();
        let mut r = Rng::new(4);
        let trace = RevisionTrace::generate(&cfg, 30, &mut r);
        let mut found = 0;
        for (a, b) in trace.pairs() {
            if let Some(s) = sample_atomic(a, b, None, &mut r) {
                found += 1;
                // Applying the sampled edit to base must move strictly
                // toward `b`: base+edit equals applying prefix+1 of script.
                let after = apply_edits(&s.base, &[s.edit]);
                assert_ne!(after, s.base);
                assert!((0.0..=1.0).contains(&s.normalized_pos));
            }
        }
        assert!(found >= 25);
    }

    #[test]
    fn atomic_sample_respects_window() {
        let cfg = TraceConfig::tiny();
        let mut r = Rng::new(5);
        let mut checked = 0;
        for _ in 0..50 {
            let a = generate_document(&cfg, &mut r);
            let b = next_revision(&cfg, &a, &mut r);
            if let Some(s) = sample_atomic(&a, &b, Some((0.0, 0.3)), &mut r) {
                // The *pre-application* location was within the window of
                // the old doc; allow slack from prefix application shifts.
                assert!(s.normalized_pos <= 0.45, "pos {}", s.normalized_pos);
                checked += 1;
            }
        }
        assert!(checked > 0);
    }
}
