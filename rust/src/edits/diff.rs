//! Token-sequence diffing (Myers O(ND)) and edit-script application.
//!
//! The offline pipeline receives whole revisions; `diff_tokens` recovers a
//! minimal edit script so the incremental engine can process just the
//! changed rows — mirroring how the paper aligns consecutive Wikipedia
//! revisions.

use super::Edit;

/// Apply an edit script to a token sequence (indices are interpreted
/// against the evolving document, left to right).
pub fn apply_edits(tokens: &[u32], edits: &[Edit]) -> Vec<u32> {
    let mut v = tokens.to_vec();
    for e in edits {
        match *e {
            Edit::Replace { at, tok } => v[at] = tok,
            Edit::Insert { at, tok } => v.insert(at, tok),
            Edit::Delete { at } => {
                v.remove(at);
            }
        }
    }
    v
}

/// LCS edit-distance (number of insertions + deletions; replacements
/// count as delete+insert here, matching the classic LCS-based measure).
pub fn edit_distance(a: &[u32], b: &[u32]) -> usize {
    lcs_trace(a, b).0
}

/// Minimal edit script turning `a` into `b`, expressed as `Edit`s with
/// left-to-right evolving indices. Adjacent delete+insert pairs at the same
/// spot are fused into `Replace` (cheaper for the engine: no position-pool
/// traffic).
pub fn diff_tokens(a: &[u32], b: &[u32]) -> Vec<Edit> {
    let (_, ops) = lcs_trace(a, b);
    // ops: per-position micro-ops over ORIGINAL indices; convert to an
    // evolving-index script, fusing Del+Ins → Replace.
    let mut script = Vec::new();
    let mut shift: isize = 0; // current index shift from earlier edits
    let mut i = 0;
    while i < ops.len() {
        match ops[i] {
            Op::Del(ai) => {
                if let Some(&Op::Ins(aj, tok)) = ops.get(i + 1) {
                    // Replace when the insertion lands where the deletion was.
                    if aj == ai + 1 {
                        script.push(Edit::Replace {
                            at: (ai as isize + shift) as usize,
                            tok,
                        });
                        i += 2;
                        continue;
                    }
                }
                script.push(Edit::Delete {
                    at: (ai as isize + shift) as usize,
                });
                shift -= 1;
                i += 1;
            }
            Op::Ins(ai, tok) => {
                script.push(Edit::Insert {
                    at: (ai as isize + shift) as usize,
                    tok,
                });
                shift += 1;
                i += 1;
            }
        }
    }
    script
}

/// Micro-op over original `a` indices: Del(i) deletes a[i]; Ins(i, tok)
/// inserts before original index i (i.e. after a[i-1]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Op {
    Del(usize),
    Ins(usize, u32),
}

/// LCS dynamic program with trace reconstruction. O(n·m) time/space —
/// documents are ≤ a few thousand tokens, so this is well within budget
/// and (unlike a hand-rolled Myers backtrack) straightforwardly correct.
fn lcs_trace(a: &[u32], b: &[u32]) -> (usize, Vec<Op>) {
    let (n, m) = (a.len(), b.len());
    // dp[i][j] = LCS length of a[..i], b[..j], flattened row-major.
    let w = m + 1;
    let mut dp = vec![0u32; (n + 1) * w];
    for i in 1..=n {
        for j in 1..=m {
            dp[i * w + j] = if a[i - 1] == b[j - 1] {
                dp[(i - 1) * w + (j - 1)] + 1
            } else {
                dp[(i - 1) * w + j].max(dp[i * w + (j - 1)])
            };
        }
    }
    let lcs = dp[n * w + m] as usize;
    let dist = n + m - 2 * lcs;

    // Backtrack from (n, m). Prefer the Ins step on ties so that the
    // reversed op list yields Del-before-Ins runs, which the Replace
    // fusion in `diff_tokens` relies on.
    let mut ops = Vec::with_capacity(dist);
    let (mut i, mut j) = (n, m);
    while i > 0 || j > 0 {
        if i > 0 && j > 0 && a[i - 1] == b[j - 1] && dp[i * w + j] == dp[(i - 1) * w + (j - 1)] + 1
        {
            i -= 1;
            j -= 1;
        } else if j > 0 && (i == 0 || dp[i * w + (j - 1)] >= dp[(i - 1) * w + j]) {
            // Insertion of b[j-1] before original index i.
            ops.push(Op::Ins(i, b[j - 1]));
            j -= 1;
        } else {
            // Deletion of a[i-1].
            ops.push(Op::Del(i - 1));
            i -= 1;
        }
    }
    ops.reverse();
    (dist, ops)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn check_roundtrip(a: &[u32], b: &[u32]) {
        let script = diff_tokens(a, b);
        let applied = apply_edits(a, &script);
        assert_eq!(applied, b, "script {script:?} failed for {a:?} -> {b:?}");
    }

    #[test]
    fn identical_sequences_empty_script() {
        let a = vec![1, 2, 3];
        assert_eq!(diff_tokens(&a, &a), vec![]);
        assert_eq!(edit_distance(&a, &a), 0);
    }

    #[test]
    fn single_ops() {
        check_roundtrip(&[1, 2, 3], &[1, 9, 3]); // replace
        check_roundtrip(&[1, 2, 3], &[1, 2, 9, 3]); // insert
        check_roundtrip(&[1, 2, 3], &[1, 3]); // delete
        check_roundtrip(&[], &[5]);
        check_roundtrip(&[5], &[]);
        check_roundtrip(&[], &[]);
    }

    #[test]
    fn replace_fusion() {
        let script = diff_tokens(&[1, 2, 3], &[1, 9, 3]);
        assert_eq!(script, vec![Edit::Replace { at: 1, tok: 9 }]);
    }

    #[test]
    fn distance_is_minimal_on_known_cases() {
        assert_eq!(edit_distance(&[1, 2, 3], &[1, 9, 3]), 2); // del+ins
        assert_eq!(edit_distance(&[1, 2, 3], &[2, 3]), 1);
        assert_eq!(edit_distance(&[1, 2, 3], &[1, 2, 3, 4]), 1);
        // ABCABBA -> CBABAC (classic Myers example, distance 5)
        let a: Vec<u32> = "ABCABBA".bytes().map(u32::from).collect();
        let b: Vec<u32> = "CBABAC".bytes().map(u32::from).collect();
        assert_eq!(edit_distance(&a, &b), 5);
        check_roundtrip(&a, &b);
    }

    #[test]
    fn random_pairs_roundtrip() {
        let mut r = Rng::new(123);
        for _ in 0..300 {
            let n = r.below(40);
            let a: Vec<u32> = (0..n).map(|_| r.below(6) as u32).collect();
            let m = r.below(40);
            let b: Vec<u32> = (0..m).map(|_| r.below(6) as u32).collect();
            check_roundtrip(&a, &b);
        }
    }

    #[test]
    fn random_mutations_roundtrip_and_small_scripts() {
        let mut r = Rng::new(77);
        for _ in 0..200 {
            let n = r.range(10, 60);
            let a: Vec<u32> = (0..n).map(|_| r.below(50) as u32).collect();
            let mut b = a.clone();
            let k = r.range(1, 5);
            for _ in 0..k {
                if b.is_empty() {
                    break;
                }
                match r.below(3) {
                    0 => {
                        let i = r.below(b.len());
                        b[i] = r.below(50) as u32;
                    }
                    1 => {
                        let i = r.below(b.len() + 1);
                        b.insert(i, r.below(50) as u32);
                    }
                    _ => {
                        let i = r.below(b.len());
                        b.remove(i);
                    }
                }
            }
            check_roundtrip(&a, &b);
            // Minimality bound: script length ≤ 2 edits per mutation.
            assert!(diff_tokens(&a, &b).len() <= 2 * k);
        }
    }
}
