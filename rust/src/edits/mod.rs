//! Document edits: atomic operations, diffing, and synthetic revision
//! traces (the substitute for the paper's scraped Wikipedia edit
//! histories — see docs/ARCHITECTURE.md).

pub mod diff;
pub mod trace;

pub use diff::{apply_edits, diff_tokens, edit_distance};
pub use trace::{RevisionTrace, TraceConfig};

/// One atomic edit, addressed by *current* row index. A sequence of edits is
/// applied left-to-right with indices interpreted against the document state
/// produced by the previous edit (standard edit-script semantics).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Edit {
    /// Replace the token at `at` with `tok`.
    Replace { at: usize, tok: u32 },
    /// Insert `tok` before row `at` (`at == len` appends).
    Insert { at: usize, tok: u32 },
    /// Delete the token at `at`.
    Delete { at: usize },
}

impl Edit {
    /// Row index the edit touches.
    pub fn at(&self) -> usize {
        match *self {
            Edit::Replace { at, .. } | Edit::Insert { at, .. } | Edit::Delete { at } => at,
        }
    }

    /// Net length change.
    pub fn len_delta(&self) -> isize {
        match self {
            Edit::Replace { .. } => 0,
            Edit::Insert { .. } => 1,
            Edit::Delete { .. } => -1,
        }
    }
}
