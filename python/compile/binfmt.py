"""VQTB binary tensor container — Python writer/reader.

Mirrors ``rust/src/util/binfmt.rs``; this is the weight/data interchange
format between the build-time Python pipeline and the Rust runtime.
"""

from __future__ import annotations

import struct
from typing import Dict

import numpy as np

MAGIC = b"VQTB"
VERSION = 1

_DTYPES = {0: np.float32, 1: np.int32}
_DTYPE_CODES = {np.dtype(np.float32): 0, np.dtype(np.int32): 1}


def write_tensors(path: str, tensors: Dict[str, np.ndarray]) -> None:
    """Write a name→array mapping (f32/i32 only) to a VQTB file."""
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<II", VERSION, len(tensors)))
        for name in sorted(tensors):
            arr = np.ascontiguousarray(tensors[name])
            if arr.dtype not in _DTYPE_CODES:
                if np.issubdtype(arr.dtype, np.floating):
                    arr = arr.astype(np.float32)
                elif np.issubdtype(arr.dtype, np.integer):
                    arr = arr.astype(np.int32)
                else:
                    raise TypeError(f"unsupported dtype {arr.dtype} for '{name}'")
            nb = name.encode("utf-8")
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", _DTYPE_CODES[arr.dtype], arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.astype("<" + arr.dtype.str[1:]).tobytes())


def read_tensors(path: str) -> Dict[str, np.ndarray]:
    """Read a VQTB file back into a name→array mapping."""
    with open(path, "rb") as f:
        if f.read(4) != MAGIC:
            raise ValueError("not a VQTB file")
        version, count = struct.unpack("<II", f.read(8))
        if version != VERSION:
            raise ValueError(f"unsupported version {version}")
        out: Dict[str, np.ndarray] = {}
        for _ in range(count):
            (name_len,) = struct.unpack("<I", f.read(4))
            name = f.read(name_len).decode("utf-8")
            dtype_code, ndim = struct.unpack("<BB", f.read(2))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim)) if ndim else ()
            n = int(np.prod(dims)) if dims else 1
            dtype = _DTYPES[dtype_code]
            data = np.frombuffer(f.read(4 * n), dtype="<" + np.dtype(dtype).str[1:])
            out[name] = data.reshape(dims).astype(dtype)
        return out
