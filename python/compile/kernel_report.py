"""L1 §Perf report: BlockSpec sweep for both Pallas kernels — VMEM
footprint and MXU-shape estimates per grid step.

interpret=True wallclock on CPU is NOT a TPU proxy, so this report is
structural: it verifies the chosen schedules fit VMEM with headroom and
states the systolic-array tile shapes each contraction maps to.

Usage: python -m compile.kernel_report
"""

from __future__ import annotations

from .kernels.attn_gelu import vmem_footprint_bytes as attn_vmem
from .kernels.vq_assign import vmem_footprint_bytes as vq_vmem

VMEM_BYTES = 16 * 1024 * 1024  # v4/v5-class per-core VMEM


def report(cfg_name: str, d: int, heads: int, q: int, n_heads: int):
    print(f"\n== {cfg_name}: d={d}, vq_heads={heads}, q={q}, attn_heads={n_heads} ==")
    chunk = d // heads
    print("vq_assign (scores matmul + argmax):")
    for bn in (64, 128, 256, 512):
        v = vq_vmem(bn, d, heads, q)
        fill = min(chunk, 128) / 128 * min(q, 128) / 128
        marker = " <== chosen" if bn == 128 else ""
        print(
            f"  block_n={bn:<4} VMEM {v/1024:8.1f} KiB ({v/VMEM_BYTES*100:4.1f}% of 16MiB)  "
            f"MXU tile ({bn}x{chunk})·({chunk}x{q}), contraction fill {fill:.2f}{marker}"
        )
    dh = d // n_heads
    print("attn_gelu (tiled causal, no online-softmax state):")
    for bq, bk in ((64, 64), (128, 128), (256, 128), (256, 256)):
        v = attn_vmem(bq, bk, d)
        marker = " <== chosen" if (bq, bk) == (128, 128) else ""
        print(
            f"  block=({bq:>3},{bk:>3}) VMEM {v/1024:8.1f} KiB ({v/VMEM_BYTES*100:4.1f}%)  "
            f"per-head qk tile ({bq}x{dh})·({dh}x{bk}){marker}"
        )


def main():
    # The serving model and the paper-scale target.
    report("vqt_mini (served)", d=128, heads=2, q=64, n_heads=4)
    report("OPT-125M scale (paper target)", d=768, heads=2, q=64, n_heads=12)
    print(
        "\nNotes: codebooks are pinned across the whole grid (index map is"
        " constant); at OPT-125M chunk width (384) the scores contraction"
        " saturates the MXU's 128-lane contraction axis. The attention"
        " kernel's independence of k-tiles (element-wise σ) is the same"
        " property that makes the L3 incremental corrections exact."
    )


if __name__ == "__main__":
    main()
