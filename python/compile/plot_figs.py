"""Render Figures 3 and 4 from the bench CSVs as ASCII scatter plots
(matplotlib is not in the offline image; the CSVs plot directly elsewhere).

Usage: python -m compile.plot_figs [fig3_offline.csv] [fig4_online.csv]
(the benches write these into the repo root)
"""

from __future__ import annotations

import math
import sys


def load(path):
    xs, ys = [], []
    with open(path) as f:
        next(f)  # header
        for line in f:
            a, b = line.strip().split(",")
            xs.append(float(a))
            ys.append(float(b))
    return xs, ys


def ascii_scatter(xs, ys, logx, logy, width=72, height=20, xlabel="", ylabel=""):
    tx = [math.log10(max(x, 1e-4)) if logx else x for x in xs]
    ty = [math.log10(max(y, 1e-2)) if logy else y for y in ys]
    x0, x1 = min(tx), max(tx)
    y0, y1 = min(ty), max(ty)
    grid = [[" "] * width for _ in range(height)]
    for a, b in zip(tx, ty):
        col = int((a - x0) / max(x1 - x0, 1e-9) * (width - 1))
        row = int((b - y0) / max(y1 - y0, 1e-9) * (height - 1))
        grid[height - 1 - row][col] = "•"
    top = f"{ylabel} (log)" if logy else ylabel
    print(top)
    for r in grid:
        print("  |" + "".join(r))
    print("  +" + "-" * width)
    lo = f"{10**x0:.3g}" if logx else f"{x0:.3g}"
    hi = f"{10**x1:.3g}" if logx else f"{x1:.3g}"
    print(f"   {lo}{' ' * (width - len(lo) - len(hi))}{hi}   {xlabel}")


def median(v):
    s = sorted(v)
    n = len(s)
    return s[n // 2] if n % 2 else 0.5 * (s[n // 2 - 1] + s[n // 2])


def main():
    fig3 = sys.argv[1] if len(sys.argv) > 1 else "fig3_offline.csv"
    fig4 = sys.argv[2] if len(sys.argv) > 2 else "fig4_online.csv"
    try:
        xs, ys = load(fig3)
        print(f"\n== Figure 3 (offline): speedup vs fraction modified — {len(xs)} pairs, median {median(ys):.1f}x ==")
        ascii_scatter(xs, ys, logx=True, logy=True, xlabel="fraction of modified tokens (log)", ylabel="speedup")
    except FileNotFoundError:
        print(f"({fig3} not found — run `cargo bench --bench fig3_offline`)")
    try:
        xs, ys = load(fig4)
        print(f"\n== Figure 4 (online): speedup vs normalized edit location — {len(xs)} edits, median {median(ys):.1f}x ==")
        ascii_scatter(xs, ys, logx=False, logy=True, xlabel="normalized edit location", ylabel="speedup")
    except FileNotFoundError:
        print(f"({fig4} not found — run `cargo bench --bench fig4_online`)")


if __name__ == "__main__":
    main()
