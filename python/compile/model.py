"""L2: the VQT model forward pass in JAX (build-time only).

Mirrors the Rust L3 dense oracle (`rust/src/model/dense.rs`) operation for
operation so AOT artifacts executed through PJRT agree numerically with the
in-process engine. The hot spots dispatch to the L1 Pallas kernels when
``use_pallas=True`` (the AOT path); training uses the pure-jnp path.

Model structure per block (pre-LN):
  x ← x + W_mix · VQ(σ(QKᵀ·s)V · c)            (attention, paper eq. 1)
  x ← x + FFN(LN2(x))
with Q/K/V from LN1(x); classifier = linear over masked mean-pool of
LN_f(x).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import ref
from .kernels.attn_gelu import attn_gelu
from .kernels.vq_assign import vq_assign


@dataclass(frozen=True)
class ModelCfg:
    """Mirror of the Rust `ModelConfig` (see `config/mod.rs`)."""

    vocab_size: int = 257
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    d_ff: int = 512
    max_seq: int = 512
    pos_pool: int = 512 * 8
    vq_heads: int = 2
    vq_codes: int = 64
    attention: str = "gelu"  # "gelu" | "softmax"
    n_classes: int = 2
    ln_eps: float = 1e-5

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads

    @property
    def out_scale(self) -> float:
        return 1.0 / float(np.sqrt(self.max_seq))


def vqt_mini() -> ModelCfg:
    return ModelCfg()


def vqt_tiny() -> ModelCfg:
    return ModelCfg(
        vocab_size=64,
        d_model=32,
        n_layers=2,
        n_heads=2,
        d_ff=64,
        max_seq=64,
        pos_pool=64 * 8,
        vq_heads=2,
        vq_codes=16,
    )


def table1_cfg(variant: str) -> ModelCfg:
    """The four Table-1 model variants at laptop scale."""
    base = dict(
        vocab_size=257,
        d_model=64,
        n_layers=2,
        n_heads=4,
        d_ff=256,
        max_seq=128,
        pos_pool=128 * 8,
        n_classes=2,
    )
    if variant == "opt":  # OPT-mini baseline
        return ModelCfg(**base, vq_heads=0, vq_codes=0, attention="softmax")
    if variant == "distil":  # DistilOPT-mini: half depth
        return ModelCfg(**{**base, "n_layers": 1}, vq_heads=0, vq_codes=0, attention="softmax")
    if variant == "vq_h2":
        return ModelCfg(**base, vq_heads=2, vq_codes=64, attention="gelu")
    if variant == "vq_h4":
        return ModelCfg(**base, vq_heads=4, vq_codes=64, attention="gelu")
    raise ValueError(f"unknown variant {variant}")


# ---------------------------------------------------------------------------
# Parameters (flat dict, names == VQTB tensor names == Rust loader names)
# ---------------------------------------------------------------------------


def init_params(cfg: ModelCfg, seed: int) -> dict:
    """Deterministic init; returns a flat {name: np.ndarray} dict."""
    rng = np.random.default_rng(seed)
    d = cfg.d_model

    def mat(r, c, s):
        return (rng.standard_normal((r, c)) * s).astype(np.float32)

    p = {
        "embed_tokens": mat(cfg.vocab_size, d, 0.02),
        "embed_pos": mat(cfg.pos_pool, d, 0.02),
        "ln_f.g": np.ones(d, np.float32),
        "ln_f.b": np.zeros(d, np.float32),
        "w_cls": mat(d, cfg.n_classes, 1.0 / np.sqrt(d)),
        "b_cls": np.zeros(cfg.n_classes, np.float32),
    }
    ps = 1.0 / np.sqrt(d)
    for i in range(cfg.n_layers):
        pre = f"layers.{i}."
        p[pre + "ln1.g"] = np.ones(d, np.float32)
        p[pre + "ln1.b"] = np.zeros(d, np.float32)
        p[pre + "wq"] = mat(d, d, ps)
        p[pre + "wk"] = mat(d, d, ps)
        p[pre + "wv"] = mat(d, d, ps)
        p[pre + "bq"] = np.zeros(d, np.float32)
        p[pre + "bk"] = np.zeros(d, np.float32)
        p[pre + "bv"] = np.zeros(d, np.float32)
        if cfg.vq_heads > 0:
            chunk = d // cfg.vq_heads
            p[pre + "vq.book"] = (
                rng.standard_normal((cfg.vq_heads, cfg.vq_codes, chunk)) / np.sqrt(chunk)
            ).astype(np.float32)
        p[pre + "w_mix"] = mat(d, d, ps)
        p[pre + "b_mix"] = np.zeros(d, np.float32)
        p[pre + "ln2.g"] = np.ones(d, np.float32)
        p[pre + "ln2.b"] = np.zeros(d, np.float32)
        p[pre + "w_ff1"] = mat(d, cfg.d_ff, ps)
        p[pre + "b_ff1"] = np.zeros(cfg.d_ff, np.float32)
        p[pre + "w_ff2"] = mat(cfg.d_ff, d, 1.0 / np.sqrt(cfg.d_ff))
        p[pre + "b_ff2"] = np.zeros(d, np.float32)
    return p


# ---------------------------------------------------------------------------
# Forward pass (single document)
# ---------------------------------------------------------------------------


def _attention_block(params, cfg: ModelCfg, li: int, x, kv_mask, use_pallas: bool, quantizer=None):
    """LN1 → QKV → attention → (VQ) — returns the pre-mix attention output
    and the per-row codes (or None).

    `quantizer(attn, books, bias) → (attn_q, codes)` overrides the hard
    VQ (training uses a straight-through estimator here).
    """
    pre = f"layers.{li}."
    h = ref.layernorm(x, params[pre + "ln1.g"], params[pre + "ln1.b"], cfg.ln_eps)
    q = h @ params[pre + "wq"] + params[pre + "bq"]
    k = h @ params[pre + "wk"] + params[pre + "bk"]
    v = h @ params[pre + "wv"] + params[pre + "bv"]
    if cfg.attention == "gelu":
        if use_pallas:
            attn = attn_gelu(q, k, v, kv_mask, cfg.n_heads, cfg.out_scale)
        else:
            attn = ref.attn_gelu_ref(q, k, v, cfg.n_heads, kv_mask, cfg.out_scale)
    else:
        attn = ref.attn_softmax_ref(q, k, v, cfg.n_heads, kv_mask, cfg.out_scale)
    codes = None
    if cfg.vq_heads > 0:
        books = params[pre + "vq.book"]
        bias = ref.vq_bias(books)
        if quantizer is not None:
            attn, codes = quantizer(attn, books, bias)
        else:
            if use_pallas:
                codes = vq_assign(attn, books, bias)
            else:
                codes = ref.vq_assign_ref(attn, books, bias)
            attn = ref.vq_decode_ref(codes, books)
    return attn, codes


def forward(params, cfg: ModelCfg, tokens, pos, length, use_pallas: bool = False, quantizer=None):
    """Single-document forward.

    tokens, pos: int32 (n,) — n is static (the artifact's bucket size);
    length: int32 scalar — rows ≥ length are padding (masked out of
    attention columns and pooling).
    Returns (logits (n_classes,), codes list per layer or Nones).
    """
    n = tokens.shape[0]
    idx = jnp.arange(n)
    kv_mask = (idx < length).astype(jnp.float32)
    x = params["embed_tokens"][tokens] + params["embed_pos"][pos]
    all_codes = []
    for li in range(cfg.n_layers):
        pre = f"layers.{li}."
        attn, codes = _attention_block(params, cfg, li, x, kv_mask, use_pallas, quantizer)
        all_codes.append(codes)
        x = x + attn @ params[pre + "w_mix"] + params[pre + "b_mix"]
        h2 = ref.layernorm(x, params[pre + "ln2.g"], params[pre + "ln2.b"], cfg.ln_eps)
        ff = ref.gelu(h2 @ params[pre + "w_ff1"] + params[pre + "b_ff1"])
        x = x + ff @ params[pre + "w_ff2"] + params[pre + "b_ff2"]
    hfin = ref.layernorm(x, params["ln_f.g"], params["ln_f.b"], cfg.ln_eps)
    pooled = jnp.sum(hfin * kv_mask[:, None], axis=0) / jnp.maximum(
        length.astype(jnp.float32), 1.0
    )
    logits = pooled @ params["w_cls"] + params["b_cls"]
    return logits, all_codes


def forward_logits(params, cfg: ModelCfg, tokens, pos, length, use_pallas: bool = False):
    """Logits-only wrapper (the AOT entry point)."""
    return forward(params, cfg, tokens, pos, length, use_pallas)[0]


# Batched training forward: vmap over (tokens, pos, length).
def batched_logits(params, cfg: ModelCfg, tokens, pos, lengths):
    return jax.vmap(lambda t, p, l: forward_logits(params, cfg, t, p, l))(
        tokens, pos, lengths
    )
