"""Training — regenerates Table 1 (document classification) at laptop scale.

The paper distills OPT-125M on the Pile, then fine-tunes on IMDB. Neither is
tractable here (docs/ARCHITECTURE.md), so each variant trains from scratch on the
synthetic sentiment corpus; what Table 1 tests — that the VQ bottleneck
retains most of the baseline's accuracy, with h=4 above h=2 — is preserved.

Variants (see `model.table1_cfg`):
  opt     — softmax attention, no VQ (OPT-mini)
  distil  — half depth (DistilOPT-mini)
  vq_h2   — GELU attention + 2-head VQ (VQ-OPT-mini h=2)
  vq_h4   — GELU attention + 4-head VQ (VQ-OPT-mini h=4)
plus `serve` — the vqt_mini serving model (used by `make artifacts` when
trained weights exist).

VQ pseudo-gradient: straight-through estimator with VQ-VAE commitment and
codebook losses. (The paper used a Gumbel-Softmax variant; STE is the
standard alternative and trains stably at this scale — recorded in
the module docs.)

Optimizer: hand-rolled Adam (optax is not in the offline image).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import binfmt
from .datagen import DataConfig, make_dataset, sample_positions
from .kernels import ref
from .model import ModelCfg, forward, init_params, table1_cfg, vqt_mini

COMMIT_BETA = 0.25


def ste_quantizer(attn, books, bias):
    """Straight-through VQ: forward uses the hard codeword, backward passes
    the identity to `attn`; commitment/codebook losses are added via
    an auxiliary term stored on the side (closure trick below)."""
    codes = ref.vq_assign_ref(attn, books, bias)
    hard = ref.vq_decode_ref(codes, books)
    # Straight-through: gradient flows to attn as identity; the codebook
    # receives gradient through the auxiliary losses only.
    out = attn + jax.lax.stop_gradient(hard - attn)
    return out, (codes, attn, hard)


def train_forward(params, cfg: ModelCfg, tokens, pos, length):
    """Forward with STE quantization; returns (logits, aux_vq_loss)."""
    aux = []

    def quantizer(attn, books, bias):
        out, (codes, pre, hard) = ste_quantizer(attn, books, bias)
        commit = jnp.mean(jnp.sum((pre - jax.lax.stop_gradient(hard)) ** 2, -1))
        codebook = jnp.mean(jnp.sum((jax.lax.stop_gradient(pre) - hard) ** 2, -1))
        aux.append(COMMIT_BETA * commit + codebook)
        return out, codes

    q = quantizer if cfg.vq_heads > 0 else None
    logits, _ = forward(params, cfg, tokens, pos, length, use_pallas=False, quantizer=q)
    vq_loss = jnp.sum(jnp.stack(aux)) if aux else jnp.float32(0.0)
    return logits, vq_loss


def make_loss_fn(cfg: ModelCfg):
    def loss_fn(params, tokens, pos, lengths, labels):
        def one(t, p, l, y):
            logits, vq_loss = train_forward(params, cfg, t, p, l)
            logp = jax.nn.log_softmax(logits)
            return -logp[y] + 0.02 * vq_loss

        losses = jax.vmap(one)(tokens, pos, lengths, labels)
        return jnp.mean(losses)

    return loss_fn


def make_eval_fn(cfg: ModelCfg):
    @jax.jit
    def eval_fn(params, tokens, pos, lengths):
        def one(t, p, l):
            logits, _ = forward(params, cfg, t, p, l, use_pallas=False)
            return jnp.argmax(logits)

        return jax.vmap(one)(tokens, pos, lengths)

    return eval_fn


# --------------------------------------------------------------------------
# Hand-rolled Adam
# --------------------------------------------------------------------------


def adam_init(params):
    zeros = {k: np.zeros_like(v) for k, v in params.items()}
    return {"m": zeros, "v": {k: np.zeros_like(v) for k, v in params.items()}, "t": 0}


def adam_step(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    state["t"] += 1
    t = state["t"]
    out = {}
    for k in params:
        g = np.asarray(grads[k])
        state["m"][k] = b1 * state["m"][k] + (1 - b1) * g
        state["v"][k] = b2 * state["v"][k] + (1 - b2) * g * g
        mhat = state["m"][k] / (1 - b1**t)
        vhat = state["v"][k] / (1 - b2**t)
        out[k] = np.asarray(params[k]) - lr * mhat / (np.sqrt(vhat) + eps)
    return out


def accuracy_f1(pred, labels):
    pred = np.asarray(pred)
    labels = np.asarray(labels)
    acc = float((pred == labels).mean())
    tp = int(((pred == 1) & (labels == 1)).sum())
    fp = int(((pred == 1) & (labels == 0)).sum())
    fn = int(((pred == 0) & (labels == 1)).sum())
    prec = tp / (tp + fp) if tp + fp else 0.0
    rec = tp / (tp + fn) if tp + fn else 0.0
    f1 = 2 * prec * rec / (prec + rec) if prec + rec else 0.0
    return acc, f1


def train_variant(
    variant: str,
    out_dir: str,
    steps: int,
    batch: int,
    lr: float,
    seed: int,
    data_cfg: DataConfig,
):
    if variant == "serve":
        cfg = vqt_mini()
    elif variant == "serve_h4":
        # vqt_mini with 4 VQ heads (Table 2's h=4 serving row).
        from dataclasses import replace
        cfg = replace(vqt_mini(), vq_heads=4)
    else:
        cfg = table1_cfg(variant)
    # The serving models read longer docs; cap doc length to their window.
    dc = data_cfg
    if variant.startswith("serve"):
        dc = DataConfig(**{**data_cfg.__dict__, "max_len": 128})
    print(f"[{variant}] cfg: d={cfg.d_model} L={cfg.n_layers} vq={cfg.vq_heads} attn={cfg.attention}")

    params = init_params(cfg, seed)
    train_toks, train_lens, train_labels = make_dataset(dc, dc.n_train, dc.seed)
    eval_toks, eval_lens, eval_labels = make_dataset(dc, dc.n_eval, dc.seed + 1)
    # Clamp PAD ids into vocab (PAD = vocab_size - 1).
    pad_id = cfg.vocab_size - 1
    train_toks = np.minimum(train_toks, pad_id)
    eval_toks = np.minimum(eval_toks, pad_id)

    rng = np.random.default_rng(seed + 17)
    loss_fn = make_loss_fn(cfg)
    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    eval_fn = make_eval_fn(cfg)
    opt = adam_init(params)

    n_train = train_toks.shape[0]
    seq = train_toks.shape[1]
    t0 = time.time()
    losses = []
    for step in range(steps):
        idx = rng.choice(n_train, size=batch, replace=False)
        pos = sample_positions(rng, batch, seq, cfg.pos_pool)
        loss, grads = grad_fn(
            {k: jnp.asarray(v) for k, v in params.items()},
            jnp.asarray(train_toks[idx]),
            jnp.asarray(pos),
            jnp.asarray(train_lens[idx]),
            jnp.asarray(train_labels[idx]),
        )
        # Linear warmup, cosine decay (paper's schedule shape).
        warm = min(1.0, (step + 1) / max(1, steps // 10))
        decay = 0.5 * (1 + np.cos(np.pi * step / steps))
        params = adam_step(params, grads, opt, lr * warm * (0.1 + 0.9 * decay))
        losses.append(float(loss))
        if (step + 1) % 50 == 0:
            print(
                f"[{variant}] step {step+1}/{steps} loss {np.mean(losses[-50:]):.4f} "
                f"({time.time()-t0:.0f}s)"
            )

    # Eval with deterministic spread positions (inference-time protocol).
    pool = cfg.pos_pool
    spread = np.array(
        [[(2 * i + 1) * pool // (2 * seq) for i in range(seq)]], dtype=np.int32
    ).repeat(eval_toks.shape[0], axis=0)
    pred = eval_fn(
        {k: jnp.asarray(v) for k, v in params.items()},
        jnp.asarray(eval_toks),
        jnp.asarray(spread),
        jnp.asarray(eval_lens),
    )
    acc, f1 = accuracy_f1(pred, eval_labels)
    print(f"[{variant}] eval accuracy {acc:.4f} f1 {f1:.4f}")

    params_np = {k: np.asarray(v) for k, v in params.items()}
    binfmt.write_tensors(os.path.join(out_dir, f"weights_trained_{variant}.bin"), params_np)
    # Export the eval set once (shared by the Rust Table-1 bench).
    eval_path = os.path.join(out_dir, "table1_eval.bin")
    if not os.path.exists(eval_path):
        binfmt.write_tensors(
            eval_path,
            {
                "tokens": eval_toks.astype(np.int32),
                "lengths": eval_lens.astype(np.int32),
                "labels": eval_labels.astype(np.int32),
            },
        )
    return {
        "variant": variant,
        "accuracy": acc,
        "f1": f1,
        "steps": steps,
        "final_loss": float(np.mean(losses[-20:])),
        "d_model": cfg.d_model,
        "n_layers": cfg.n_layers,
        "vq_heads": cfg.vq_heads,
        "attention": cfg.attention,
        "train_seconds": time.time() - t0,
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument(
        "--variants",
        default="opt,distil,vq_h2,vq_h4",
        help="comma-separated subset of opt,distil,vq_h2,vq_h4,serve,serve_h4",
    )
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--seed", type=int, default=7)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    dc = DataConfig()
    results = []
    for v in args.variants.split(","):
        results.append(
            train_variant(v.strip(), args.out, args.steps, args.batch, args.lr, args.seed, dc)
        )
    path = os.path.join(args.out, "table1_results.json")
    existing = []
    if os.path.exists(path):
        with open(path) as f:
            existing = [r for r in json.load(f) if r["variant"] not in {x["variant"] for x in results}]
    with open(path, "w") as f:
        json.dump(existing + results, f, indent=2)
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
