"""Synthetic sentiment corpus — the IMDB substitute (docs/ARCHITECTURE.md).

Documents are byte-token sequences. Sentiment is carried by two small
lexicons of "positive" and "negative" tokens sprinkled through neutral
filler; the label is the majority lexicon. This preserves the structure the
paper's Table 1 exercises: long documents, a classification head over
pooled representations, and distributed (non-local) evidence.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import numpy as np

VOCAB = 256
PAD = 256  # reserved id (vocab_size = 257 in ModelConfig)

POS_LEXICON = np.arange(200, 216)  # 16 "positive" tokens
NEG_LEXICON = np.arange(216, 232)  # 16 "negative" tokens


@dataclass
class DataConfig:
    n_train: int = 2048
    n_eval: int = 512
    min_len: int = 48
    max_len: int = 128
    # Mean count of sentiment-bearing tokens per document.
    evidence_mean: float = 10.0
    # Probability a sentiment token agrees with the label (label-noise knob).
    agree_p: float = 0.8
    seed: int = 1234


def make_document(rng: np.random.Generator, cfg: DataConfig) -> Tuple[np.ndarray, int]:
    """One (tokens, label) pair."""
    n = int(rng.integers(cfg.min_len, cfg.max_len + 1))
    label = int(rng.integers(0, 2))
    # Neutral filler avoids the lexicon ranges.
    doc = rng.integers(0, 200, size=n).astype(np.int32)
    k = max(2, int(rng.poisson(cfg.evidence_mean)))
    slots = rng.choice(n, size=min(k, n), replace=False)
    for s in slots:
        agree = rng.random() < cfg.agree_p
        lex = (POS_LEXICON if label == 1 else NEG_LEXICON) if agree else (
            NEG_LEXICON if label == 1 else POS_LEXICON
        )
        doc[s] = lex[rng.integers(0, len(lex))]
    return doc, label


def make_dataset(cfg: DataConfig, n: int, seed: int):
    """Padded batch: tokens (n, max_len) with PAD, lengths, labels."""
    rng = np.random.default_rng(seed)
    toks = np.full((n, cfg.max_len), PAD, dtype=np.int32)
    lengths = np.zeros(n, dtype=np.int32)
    labels = np.zeros(n, dtype=np.int32)
    for i in range(n):
        d, y = make_document(rng, cfg)
        toks[i, : len(d)] = d
        lengths[i] = len(d)
        labels[i] = y
    return toks, lengths, labels


def sample_positions(rng: np.random.Generator, n_rows: int, length: int, pool: int):
    """Sampled absolute positions (paper §3.3 / App. B): per document, a
    random ordered subset of the position pool; pad rows keep increasing
    positions too (masked out of attention)."""
    out = np.zeros((n_rows, length), dtype=np.int32)
    for i in range(n_rows):
        out[i] = np.sort(rng.choice(pool, size=length, replace=False))
    return out
