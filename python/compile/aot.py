"""AOT build: lower the L2 JAX model (with L1 Pallas kernels) to HLO *text*
artifacts and export weights, so the Rust runtime is self-contained.

Interchange is HLO text, NOT a serialized HloModuleProto: jax ≥ 0.5 emits
protos with 64-bit instruction ids that xla_extension 0.5.1 (behind the
`xla` crate) rejects; the text parser reassigns ids and round-trips cleanly
(see /opt/xla-example/README.md).

Outputs under artifacts/:
  weights_serve.bin            — VQTB weights for the serving model
  model_fwd_n{N}.hlo.txt       — dense VQT forward at bucket length N
  baseline_fwd_n{N}.hlo.txt    — softmax/no-VQ baseline at bucket length N
  vq_assign_n{N}.hlo.txt       — standalone L1 VQ-assignment kernel
  manifest.json                — param argument order + artifact index

Artifact signature: (params..., tokens i32[N], pos i32[N], length i32[])
→ (logits f32[classes],). Params are passed as arguments (not baked as
constants) in sorted-name order — the same order Rust's BTreeMap yields.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import binfmt
from .kernels.ref import vq_bias
from .kernels.vq_assign import vq_assign
from .model import ModelCfg, forward_logits, init_params, vqt_mini, vqt_tiny

BUCKETS = (32, 64, 128, 256, 512)


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple=True so the Rust
    side unwraps with `to_tuple1`)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_forward(cfg: ModelCfg, params: dict, n: int, use_pallas: bool) -> str:
    """Lower forward_logits at sequence bucket n with params as arguments."""
    names = sorted(params)
    specs = [jax.ShapeDtypeStruct(params[k].shape, params[k].dtype) for k in names]
    tok_spec = jax.ShapeDtypeStruct((n,), jnp.int32)
    len_spec = jax.ShapeDtypeStruct((), jnp.int32)

    def fn(*args):
        p = dict(zip(names, args[: len(names)]))
        tokens, pos, length = args[len(names) :]
        return (forward_logits(p, cfg, tokens, pos, length, use_pallas=use_pallas),)

    lowered = jax.jit(fn).lower(*specs, tok_spec, tok_spec, len_spec)
    return to_hlo_text(lowered)


def lower_vq_assign(cfg: ModelCfg, params: dict, n: int) -> str:
    """Standalone L1 kernel artifact: (x (n,d), books (H,q,chunk),
    bias (H,q)) → codes (n, H). Codebooks are arguments rather than baked
    constants: xla_extension 0.5.1's HLO text parser mis-handles large
    multi-dim constants (verified empirically — constants round-trip to
    zeros), while parameters round-trip fine."""
    books = params["layers.0.vq.book"]

    def fn(x, b, bias):
        return (vq_assign(x, b, bias),)

    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((n, cfg.d_model), jnp.float32),
        jax.ShapeDtypeStruct(books.shape, jnp.float32),
        jax.ShapeDtypeStruct(books.shape[:2], jnp.float32),
    )
    return to_hlo_text(lowered)


def build(out_dir: str, preset: str, buckets, seed: int, weights_path: str | None):
    os.makedirs(out_dir, exist_ok=True)
    cfg = {"vqt_mini": vqt_mini, "vqt_tiny": vqt_tiny}[preset]()
    if weights_path and os.path.exists(weights_path):
        params = binfmt.read_tensors(weights_path)
        print(f"loaded trained weights from {weights_path}")
    else:
        params = init_params(cfg, seed)
        print(f"using deterministic random init (seed {seed})")
    buckets = [b for b in buckets if b <= cfg.max_seq]

    binfmt.write_tensors(os.path.join(out_dir, "weights_serve.bin"), params)

    manifest = {
        "preset": preset,
        "param_order": sorted(params),
        "buckets": list(buckets),
        "artifacts": {},
        "config": {
            "vocab_size": cfg.vocab_size,
            "d_model": cfg.d_model,
            "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads,
            "d_ff": cfg.d_ff,
            "max_seq": cfg.max_seq,
            "pos_pool": cfg.pos_pool,
            "vq_heads": cfg.vq_heads,
            "vq_codes": cfg.vq_codes,
            "attention": cfg.attention,
            "n_classes": cfg.n_classes,
            "ln_eps": cfg.ln_eps,
        },
    }

    for n in buckets:
        name = f"model_fwd_n{n}.hlo.txt"
        text = lower_forward(cfg, params, n, use_pallas=True)
        with open(os.path.join(out_dir, name), "w") as f:
            f.write(text)
        manifest["artifacts"][f"model_fwd_n{n}"] = name
        print(f"wrote {name} ({len(text)} chars)")

    # Standalone L1 kernel artifact at the largest bucket.
    if cfg.vq_heads > 0 and buckets:
        n = buckets[-1]
        name = f"vq_assign_n{n}.hlo.txt"
        with open(os.path.join(out_dir, name), "w") as f:
            f.write(lower_vq_assign(cfg, params, n))
        manifest["artifacts"][f"vq_assign_n{n}"] = name
        print(f"wrote {name}")

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest.json ({len(manifest['artifacts'])} artifacts)")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--preset", default="vqt_mini", choices=["vqt_mini", "vqt_tiny"])
    ap.add_argument("--buckets", default=",".join(map(str, BUCKETS)))
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument(
        "--weights",
        default="../artifacts/weights_trained_serve.bin",
        help="use trained weights if present (falls back to random init)",
    )
    args = ap.parse_args()
    buckets = [int(b) for b in args.buckets.split(",") if b]
    build(args.out, args.preset, buckets, args.seed, args.weights)


if __name__ == "__main__":
    main()
