"""Pallas kernel: multi-head VQ assignment (L1 hot-spot #1).

TPU adaptation of the paper's VQ layer (docs/ARCHITECTURE.md): assignment uses the
inner-product form  argmin‖x−c‖ = argmax(x·c + b)  from App. A.2, so each
head's scoring is a single `(block_n, chunk) × (chunk, q)` matmul — an
MXU-shaped contraction — followed by a row argmax (VPU reduction).

BlockSpec schedule: a 1-D grid tiles the sequence; each grid step holds one
`(block_n, d)` activation tile plus ALL codebooks in VMEM (the codebooks are
tiny: H·q·chunk = d·q floats — e.g. 32 KiB for d=128, q=64 — and are pinned
across the whole grid). This replaces what a CUDA port would do with one
threadblock per row.

Always lowered with `interpret=True`: the CPU PJRT plugin cannot execute
Mosaic custom-calls; real-TPU estimates are reported in docs/ARCHITECTURE.md.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _vq_assign_kernel(x_ref, books_ref, bias_ref, codes_ref, *, heads: int):
    """One sequence tile: scores = x_h @ C_hᵀ + b_h, codes = argmax."""
    x = x_ref[...]  # (bn, d)
    books = books_ref[...]  # (H, q, chunk)
    bias = bias_ref[...]  # (H, q)
    bn, d = x.shape
    chunk = d // heads
    # Unrolled per-head loop (H is small and static): each head is one
    # (bn, chunk) × (chunk, q) matmul on the MXU.
    codes = []
    for h in range(heads):
        xh = x[:, h * chunk : (h + 1) * chunk]
        scores = jnp.dot(xh, books[h].T) + bias[h][None, :]  # (bn, q)
        codes.append(jnp.argmax(scores, axis=-1).astype(jnp.int32))
    codes_ref[...] = jnp.stack(codes, axis=-1)  # (bn, H)


@functools.partial(jax.jit, static_argnames=("block_n",))
def vq_assign(x, books, bias, block_n: int = 128):
    """Multi-head VQ assignment via Pallas.

    x: (n, d) activations; books: (H, q, d/H); bias: (H, q).
    Returns codes (n, H) int32. `n` must be a multiple of `block_n` or
    smaller than it (single tile).
    """
    n, d = x.shape
    heads, q, chunk = books.shape
    assert d == heads * chunk, "codebook chunking mismatch"
    bn = min(block_n, n)
    assert n % bn == 0, f"sequence {n} not tileable by {bn}"
    grid = (n // bn,)
    return pl.pallas_call(
        functools.partial(_vq_assign_kernel, heads=heads),
        out_shape=jax.ShapeDtypeStruct((n, heads), jnp.int32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),  # stream tiles
            pl.BlockSpec((heads, q, chunk), lambda i: (0, 0, 0)),  # pinned
            pl.BlockSpec((heads, q), lambda i: (0, 0)),  # pinned
        ],
        out_specs=pl.BlockSpec((bn, heads), lambda i: (i, 0)),
        interpret=True,
    )(x, books, bias)


def vmem_footprint_bytes(block_n: int, d: int, heads: int, q: int) -> int:
    """Estimated VMEM bytes per grid step (f32): stream tile + codebooks +
    bias + codes tile. Used by the §Perf BlockSpec sweep."""
    chunk = d // heads
    return 4 * (block_n * d + heads * q * chunk + heads * q + block_n * heads)
