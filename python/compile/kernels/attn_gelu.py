"""Pallas kernel: causal multi-head GELU-elementwise attention (L1 #2).

The paper replaces softmax with an element-wise non-linearity (eq. 1)
precisely so incremental column corrections are exact. On TPU this also
*simplifies* the flash-attention schedule: without softmax there is no
online max/denominator state — each (query-tile × key-tile) contribution is
independent, so the kernel is a plain 2-D tiled matmul-accumulate:

  grid = (q_tiles, k_tiles); out[qi] += gelu(Q[qi]·K[kj]ᵀ·s) ⊙ mask · V[kj]

with an f32 VMEM accumulator tile and the causal/pad mask applied in
coefficient space (gelu(s)·0 = 0, exact). K-tiles beyond the diagonal are
skipped entirely via `pl.when`-style masking of whole tiles.

Always lowered with `interpret=True` (CPU PJRT cannot run Mosaic).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import gelu


def _attn_kernel(
    q_ref,
    k_ref,
    v_ref,
    mask_ref,
    o_ref,
    *,
    n_heads: int,
    out_scale: float,
    block_q: int,
    block_k: int,
):
    qi = pl.program_id(0)
    kj = pl.program_id(1)
    q = q_ref[...]  # (bq, d)
    k = k_ref[...]  # (bk, d)
    v = v_ref[...]  # (bk, d)
    kv_mask = mask_ref[...]  # (bk,)
    bq, d = q.shape
    bk = k.shape[0]
    dh = d // n_heads
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))

    # Global row/col ids for the causal mask.
    rows = qi * block_q + jax.lax.iota(jnp.int32, bq)
    cols = kj * block_k + jax.lax.iota(jnp.int32, bk)
    causal = (rows[:, None] >= cols[None, :]).astype(jnp.float32)
    m = causal * kv_mask[None, :]

    parts = []
    for h in range(n_heads):
        qh = q[:, h * dh : (h + 1) * dh]
        kh = k[:, h * dh : (h + 1) * dh]
        vh = v[:, h * dh : (h + 1) * dh]
        coeff = gelu(jnp.dot(qh, kh.T) * scale) * m  # (bq, bk)
        parts.append(jnp.dot(coeff, vh))
    acc = jnp.concatenate(parts, axis=1) if n_heads > 1 else parts[0]

    # Accumulate across k-tiles: first tile initializes, rest add.
    @pl.when(kj == 0)
    def _init():
        o_ref[...] = acc * out_scale

    @pl.when(kj > 0)
    def _acc():
        o_ref[...] += acc * out_scale


@functools.partial(jax.jit, static_argnames=("n_heads", "out_scale", "block_q", "block_k"))
def attn_gelu(q, k, v, kv_mask, n_heads: int, out_scale: float, block_q: int = 128, block_k: int = 128):
    """Tiled causal GELU attention. q/k/v: (n, d); kv_mask: (n,) float.

    Returns (n, d). `n` must tile by the block sizes (or be ≤ them).
    """
    n, d = q.shape
    bq = min(block_q, n)
    bk = min(block_k, n)
    assert n % bq == 0 and n % bk == 0, f"sequence {n} not tileable by ({bq},{bk})"
    grid = (n // bq, n // bk)
    return pl.pallas_call(
        functools.partial(
            _attn_kernel,
            n_heads=n_heads,
            out_scale=out_scale,
            block_q=bq,
            block_k=bk,
        ),
        out_shape=jax.ShapeDtypeStruct((n, d), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bk, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bk, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bk,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
        interpret=True,
    )(q, k, v, kv_mask)


def vmem_footprint_bytes(block_q: int, block_k: int, d: int) -> int:
    """Estimated VMEM bytes per grid step (f32): Q, K, V, mask, coeff, out."""
    return 4 * (block_q * d + 2 * block_k * d + block_k + block_q * block_k + block_q * d)
