"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness).

Everything here mirrors the Rust L3 arithmetic exactly (same GELU tanh
approximation, same layernorm formula, same attention masking), so the
chain  Pallas kernel == this reference == Rust dense forward  gives
end-to-end numerical parity across all three layers.
"""

from __future__ import annotations

import jax.numpy as jnp


def gelu(x):
    """GELU, tanh approximation — matches `tensor::gelu_scalar` in Rust and
    `jax.nn.gelu(approximate=True)`."""
    c = 0.7978845608028654  # sqrt(2/pi)
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x * x * x)))


def layernorm(x, g, b, eps):
    """Row-wise layernorm over the last axis (biased variance, like Rust)."""
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mean) ** 2, axis=-1, keepdims=True)
    return (x - mean) / jnp.sqrt(var + eps) * g + b


def vq_bias(books):
    """b = −‖c‖²/2 per head/code: (H, q)."""
    return -0.5 * jnp.sum(books * books, axis=-1)


def vq_scores_ref(x, books, bias):
    """Multi-head VQ scores (App. A.2 inner-product form).

    x:     (n, d)
    books: (H, q, d/H)
    bias:  (H, q) — the −‖c‖²/2 terms
    →      (n, H, q)
    """
    n, _ = x.shape
    h, _, chunk = books.shape
    xh = x.reshape(n, h, chunk)
    scores = jnp.einsum("nhc,hqc->nhq", xh, books)
    return scores + bias[None, :, :]


def vq_assign_ref(x, books, bias):
    """Nearest-codeword indices per head: (n, H) int32."""
    return jnp.argmax(vq_scores_ref(x, books, bias), axis=-1).astype(jnp.int32)


def vq_decode_ref(codes, books):
    """Gather codewords and concatenate chunks: (n, H) → (n, d)."""
    h = books.shape[0]
    parts = [books[i][codes[:, i]] for i in range(h)]
    return jnp.concatenate(parts, axis=-1)


def attn_gelu_ref(q, k, v, n_heads, kv_mask, out_scale):
    """Causal multi-head GELU-elementwise attention (paper eq. 1).

    q, k, v: (n, d); kv_mask: (n,) 1/0 float over key/value columns.
    out_i = out_scale · Σ_{j≤i} gelu(q_i·k_j/√d_h) ⊙ v_j   (per head)
    """
    n, d = q.shape
    dh = d // n_heads
    qh = q.reshape(n, n_heads, dh)
    kh = k.reshape(n, n_heads, dh)
    vh = v.reshape(n, n_heads, dh)
    scores = jnp.einsum("ihd,jhd->hij", qh, kh) / jnp.sqrt(jnp.float32(dh))
    coeff = gelu(scores)
    causal = jnp.tril(jnp.ones((n, n), dtype=coeff.dtype))
    coeff = coeff * causal[None, :, :] * kv_mask[None, None, :]
    out = jnp.einsum("hij,jhd->ihd", coeff, vh)
    return out.reshape(n, d) * out_scale


def attn_softmax_ref(q, k, v, n_heads, kv_mask, out_scale):
    """Softmax baseline attention (OPT-style), same masking conventions."""
    n, d = q.shape
    dh = d // n_heads
    qh = q.reshape(n, n_heads, dh)
    kh = k.reshape(n, n_heads, dh)
    vh = v.reshape(n, n_heads, dh)
    scores = jnp.einsum("ihd,jhd->hij", qh, kh) / jnp.sqrt(jnp.float32(dh))
    causal = jnp.tril(jnp.ones((n, n), dtype=scores.dtype))
    mask = causal[None, :, :] * kv_mask[None, None, :]
    scores = jnp.where(mask > 0, scores, -1e9)
    coeff = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    coeff = coeff / jnp.sum(coeff, axis=-1, keepdims=True)
    out = jnp.einsum("hij,jhd->ihd", coeff, vh)
    return out.reshape(n, d) * out_scale
