"""Substrate tests: VQTB container round-trip and the synthetic corpus."""

import numpy as np
import pytest

from compile import binfmt
from compile.datagen import (
    DataConfig,
    NEG_LEXICON,
    PAD,
    POS_LEXICON,
    make_dataset,
    sample_positions,
)


def test_binfmt_roundtrip(tmp_path):
    path = str(tmp_path / "t.bin")
    tensors = {
        "a": np.arange(12, dtype=np.float32).reshape(3, 4),
        "b.ids": np.array([-1, 5, 9], dtype=np.int32),
        "scalarish": np.array([3.5], dtype=np.float32),
    }
    binfmt.write_tensors(path, tensors)
    back = binfmt.read_tensors(path)
    assert set(back) == set(tensors)
    for k in tensors:
        np.testing.assert_array_equal(back[k], tensors[k])
        assert back[k].dtype == tensors[k].dtype


def test_binfmt_rejects_garbage(tmp_path):
    path = str(tmp_path / "bad.bin")
    with open(path, "wb") as f:
        f.write(b"NOPEnope")
    with pytest.raises(ValueError):
        binfmt.read_tensors(path)


def test_dataset_shapes_and_labels():
    cfg = DataConfig(min_len=20, max_len=40)
    toks, lens, labels = make_dataset(cfg, 64, seed=0)
    assert toks.shape == (64, 40)
    assert ((lens >= 20) & (lens <= 40)).all()
    assert set(np.unique(labels)) <= {0, 1}
    # Pad region is PAD.
    for i in range(64):
        assert (toks[i, lens[i] :] == PAD).all()
        assert (toks[i, : lens[i]] != PAD).all()


def test_dataset_is_learnable_by_lexicon_count():
    """The Bayes-ish rule (count lexicon hits) must beat chance easily —
    otherwise Table 1 training could not separate model variants."""
    cfg = DataConfig()
    toks, lens, labels = make_dataset(cfg, 512, seed=1)
    correct = 0
    for i in range(512):
        doc = toks[i, : lens[i]]
        p = np.isin(doc, POS_LEXICON).sum()
        n = np.isin(doc, NEG_LEXICON).sum()
        correct += int((1 if p >= n else 0) == labels[i])
    assert correct / 512 > 0.85


def test_dataset_deterministic():
    cfg = DataConfig()
    a = make_dataset(cfg, 32, seed=9)
    b = make_dataset(cfg, 32, seed=9)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


def test_sample_positions_sorted_in_pool():
    rng = np.random.default_rng(0)
    pos = sample_positions(rng, 8, 32, 256)
    assert pos.shape == (8, 32)
    assert (np.diff(pos, axis=1) > 0).all()
    assert pos.min() >= 0 and pos.max() < 256
