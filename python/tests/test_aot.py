"""AOT build smoke tests (vqt_tiny preset: fast to lower)."""

import json
import os

import numpy as np
import pytest

from compile import binfmt
from compile.aot import build, lower_forward, to_hlo_text
from compile.model import init_params, vqt_tiny


def test_lower_forward_emits_hlo_text():
    cfg = vqt_tiny()
    params = init_params(cfg, 1)
    text = lower_forward(cfg, params, 16, use_pallas=True)
    assert "HloModule" in text
    assert "f32[2]" in text  # logits output
    # Params are arguments, not constants: count parameter declarations.
    assert text.count("parameter(") >= len(params) + 3


def test_build_tiny_bundle(tmp_path):
    out = str(tmp_path / "artifacts")
    build(out, "vqt_tiny", [16, 32], seed=3, weights_path=None)
    with open(os.path.join(out, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["buckets"] == [16, 32]
    assert "model_fwd_n16" in manifest["artifacts"]
    assert "vq_assign_n32" in manifest["artifacts"]
    weights = binfmt.read_tensors(os.path.join(out, "weights_serve.bin"))
    assert manifest["param_order"] == sorted(weights)
    for art in manifest["artifacts"].values():
        path = os.path.join(out, art)
        assert os.path.getsize(path) > 100
        with open(path) as f:
            assert "HloModule" in f.read(200)
    # Config block mirrors the preset.
    assert manifest["config"]["d_model"] == vqt_tiny().d_model
    assert manifest["config"]["attention"] == "gelu"
