"""L2 model tests: shapes, masking semantics, pallas/jnp path parity, and
training-forward gradients."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (
    ModelCfg,
    forward,
    forward_logits,
    init_params,
    table1_cfg,
    vqt_tiny,
)


@pytest.fixture(scope="module")
def tiny():
    cfg = vqt_tiny()
    params = {k: jnp.asarray(v) for k, v in init_params(cfg, 3).items()}
    return cfg, params


def spread_positions(n, pool):
    return jnp.array([(2 * i + 1) * pool // (2 * n) for i in range(n)], dtype=jnp.int32)


def test_forward_shapes_and_codes(tiny):
    cfg, params = tiny
    n = 16
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, n).astype(np.int32))
    pos = spread_positions(n, cfg.pos_pool)
    logits, codes = forward(params, cfg, toks, pos, jnp.int32(n))
    assert logits.shape == (cfg.n_classes,)
    assert len(codes) == cfg.n_layers
    assert codes[0].shape == (n, cfg.vq_heads)
    assert bool(jnp.all(codes[0] >= 0)) and bool(jnp.all(codes[0] < cfg.vq_codes))


def test_padding_invariance(tiny):
    """Logits must not depend on pad-row contents (mask correctness)."""
    cfg, params = tiny
    n, length = 16, 10
    rng = np.random.default_rng(1)
    toks = rng.integers(0, cfg.vocab_size, n).astype(np.int32)
    pos = spread_positions(n, cfg.pos_pool)
    l1 = forward_logits(params, cfg, jnp.asarray(toks), pos, jnp.int32(length))
    toks2 = toks.copy()
    toks2[length:] = (toks2[length:] + 7) % cfg.vocab_size
    l2 = forward_logits(params, cfg, jnp.asarray(toks2), pos, jnp.int32(length))
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-6)


def test_pallas_path_matches_jnp_path(tiny):
    cfg, params = tiny
    n = 32
    rng = np.random.default_rng(2)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, n).astype(np.int32))
    pos = spread_positions(n, cfg.pos_pool)
    a = forward_logits(params, cfg, toks, pos, jnp.int32(n), use_pallas=False)
    b = forward_logits(params, cfg, toks, pos, jnp.int32(n), use_pallas=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4, rtol=1e-4)


def test_softmax_baseline_runs():
    cfg = table1_cfg("opt")
    params = {k: jnp.asarray(v) for k, v in init_params(cfg, 5).items()}
    n = 16
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, n).astype(np.int32))
    pos = spread_positions(n, cfg.pos_pool)
    logits, codes = forward(params, cfg, toks, pos, jnp.int32(n))
    assert np.all(np.isfinite(np.asarray(logits)))
    assert all(c is None for c in codes)


def test_train_forward_has_gradients():
    from compile.train import make_loss_fn

    cfg = table1_cfg("vq_h2")
    params = {k: jnp.asarray(v) for k, v in init_params(cfg, 7).items()}
    rng = np.random.default_rng(4)
    b, n = 2, 32
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size - 1, (b, n)).astype(np.int32))
    pos = jnp.asarray(
        np.sort(rng.choice(cfg.pos_pool, size=(b, n), replace=False), axis=-1).astype(np.int32)
    )
    lens = jnp.asarray(np.array([n, n - 5], np.int32))
    labels = jnp.asarray(np.array([0, 1], np.int32))
    loss_fn = make_loss_fn(cfg)
    loss, grads = jax.value_and_grad(loss_fn)(params, toks, pos, lens, labels)
    assert np.isfinite(float(loss))
    # Codebooks must receive gradient (via the VQ-VAE codebook loss).
    g = np.asarray(grads["layers.0.vq.book"])
    assert np.abs(g).max() > 0
    # And the embedding too (via the straight-through path).
    assert np.abs(np.asarray(grads["embed_tokens"])).max() > 0


def test_variant_configs():
    assert table1_cfg("opt").vq_heads == 0
    assert table1_cfg("distil").n_layers == table1_cfg("opt").n_layers // 2
    assert table1_cfg("vq_h2").vq_heads == 2
    assert table1_cfg("vq_h4").vq_heads == 4
    with pytest.raises(ValueError):
        table1_cfg("nope")
