"""Training-loop smoke tests (fast: 3 steps, tiny variant)."""

import numpy as np
import jax.numpy as jnp

from compile.train import accuracy_f1, adam_init, adam_step


def test_adam_moves_params_toward_gradient():
    params = {"w": np.ones(4, np.float32)}
    grads = {"w": np.array([1.0, -1.0, 0.5, 0.0], np.float32)}
    state = adam_init(params)
    out = adam_step(params, grads, state, lr=0.1)
    # Positive gradient ⇒ parameter decreases; zero gradient ⇒ unchanged.
    assert out["w"][0] < 1.0
    assert out["w"][1] > 1.0
    assert abs(out["w"][3] - 1.0) < 1e-6
    # Bias correction: first step magnitude ≈ lr.
    assert abs(abs(out["w"][0] - 1.0) - 0.1) < 1e-3


def test_accuracy_f1_known_values():
    pred = np.array([1, 0, 1, 1])
    labels = np.array([1, 0, 0, 1])
    acc, f1 = accuracy_f1(pred, labels)
    assert abs(acc - 0.75) < 1e-9
    # tp=2, fp=1, fn=0 → prec 2/3, rec 1 → f1 = 0.8
    assert abs(f1 - 0.8) < 1e-9


def test_ste_quantizer_roundtrip():
    from compile.kernels import ref
    from compile.train import ste_quantizer

    rng = np.random.default_rng(0)
    books = rng.standard_normal((2, 8, 4)).astype(np.float32)
    bias = np.asarray(ref.vq_bias(books))
    x = rng.standard_normal((6, 8)).astype(np.float32)
    out, (codes, pre, hard) = ste_quantizer(jnp.array(x), jnp.array(books), jnp.array(bias))
    # Forward value equals the hard codeword.
    np.testing.assert_allclose(np.asarray(out), np.asarray(hard), atol=1e-6)
    assert codes.shape == (6, 2)


def test_kernel_report_runs(capsys):
    from compile.kernel_report import main

    main()
    out = capsys.readouterr().out
    assert "vq_assign" in out and "attn_gelu" in out
    assert "OPT-125M" in out
