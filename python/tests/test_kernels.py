"""L1 correctness: Pallas kernels vs the pure-jnp references, swept over
shapes/dtypes with hypothesis. This is the CORE kernel correctness signal."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.attn_gelu import attn_gelu, vmem_footprint_bytes as attn_vmem
from compile.kernels.vq_assign import vq_assign, vmem_footprint_bytes as vq_vmem


def rand(rng, *shape):
    return rng.standard_normal(shape).astype(np.float32)


# ---------------------------------------------------------------------------
# VQ assignment kernel
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    n_tiles=st.integers(1, 4),
    block=st.sampled_from([8, 16, 32]),
    heads=st.sampled_from([1, 2, 4]),
    q=st.sampled_from([8, 16, 64]),
    chunk=st.sampled_from([8, 16]),
    seed=st.integers(0, 2**16),
)
def test_vq_assign_matches_ref(n_tiles, block, heads, q, chunk, seed):
    rng = np.random.default_rng(seed)
    n, d = n_tiles * block, heads * chunk
    x = rand(rng, n, d)
    books = rand(rng, heads, q, chunk)
    bias = np.asarray(ref.vq_bias(books))
    got = vq_assign(jnp.array(x), jnp.array(books), jnp.array(bias), block_n=block)
    want = ref.vq_assign_ref(jnp.array(x), jnp.array(books), jnp.array(bias))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_vq_assign_is_euclidean_nearest():
    rng = np.random.default_rng(0)
    heads, q, chunk, n = 2, 16, 8, 32
    x = rand(rng, n, heads * chunk)
    books = rand(rng, heads, q, chunk)
    bias = np.asarray(ref.vq_bias(books))
    codes = np.asarray(vq_assign(jnp.array(x), jnp.array(books), jnp.array(bias), block_n=32))
    for i in range(n):
        for h in range(heads):
            xh = x[i, h * chunk : (h + 1) * chunk]
            dists = ((books[h] - xh) ** 2).sum(-1)
            assert codes[i, h] == int(np.argmin(dists))


def test_vq_assign_idempotent_on_codewords():
    rng = np.random.default_rng(1)
    heads, q, chunk = 2, 16, 8
    books = rand(rng, heads, q, chunk)
    bias = np.asarray(ref.vq_bias(books))
    # Every concatenated pair of codewords must map to itself.
    idx = rng.integers(0, q, size=(16, heads)).astype(np.int32)
    x = np.asarray(ref.vq_decode_ref(jnp.array(idx), jnp.array(books)))
    codes = np.asarray(vq_assign(jnp.array(x), jnp.array(books), jnp.array(bias), block_n=16))
    np.testing.assert_array_equal(codes, idx)


# ---------------------------------------------------------------------------
# GELU attention kernel
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    n_tiles=st.integers(1, 4),
    block=st.sampled_from([8, 16]),
    n_heads=st.sampled_from([1, 2, 4]),
    dh=st.sampled_from([4, 8]),
    frac=st.floats(0.3, 1.0),
    seed=st.integers(0, 2**16),
)
def test_attn_gelu_matches_ref(n_tiles, block, n_heads, dh, frac, seed):
    rng = np.random.default_rng(seed)
    n, d = n_tiles * block, n_heads * dh
    q, k, v = rand(rng, n, d), rand(rng, n, d), rand(rng, n, d)
    mask = (np.arange(n) < max(1, int(frac * n))).astype(np.float32)
    scale = 1.0 / np.sqrt(64.0)
    got = attn_gelu(
        jnp.array(q), jnp.array(k), jnp.array(v), jnp.array(mask),
        n_heads, float(scale), block_q=block, block_k=block,
    )
    want = ref.attn_gelu_ref(
        jnp.array(q), jnp.array(k), jnp.array(v), n_heads, jnp.array(mask), float(scale)
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5)


def test_attn_gelu_causality():
    """Row i must not depend on keys/values after i."""
    rng = np.random.default_rng(3)
    n, d, nh = 32, 16, 2
    q, k, v = rand(rng, n, d), rand(rng, n, d), rand(rng, n, d)
    mask = np.ones(n, np.float32)
    base = np.asarray(attn_gelu(jnp.array(q), jnp.array(k), jnp.array(v), jnp.array(mask), nh, 1.0, block_q=16, block_k=16))
    k2, v2 = k.copy(), v.copy()
    k2[20] += 5.0
    v2[20] -= 3.0
    pert = np.asarray(attn_gelu(jnp.array(q), jnp.array(k2), jnp.array(v2), jnp.array(mask), nh, 1.0, block_q=16, block_k=16))
    np.testing.assert_array_equal(base[:20], pert[:20])
    assert np.abs(base[20:] - pert[20:]).max() > 0


def test_attn_gelu_mask_zeroes_columns():
    rng = np.random.default_rng(4)
    n, d, nh = 16, 8, 2
    q, k, v = rand(rng, n, d), rand(rng, n, d), rand(rng, n, d)
    full = np.ones(n, np.float32)
    half = (np.arange(n) < 8).astype(np.float32)
    a = np.asarray(attn_gelu(jnp.array(q), jnp.array(k), jnp.array(v), jnp.array(half), nh, 1.0, block_q=8, block_k=8))
    # Equivalent to shrinking K/V to the first 8 rows.
    b_full = np.asarray(ref.attn_gelu_ref(jnp.array(q), jnp.array(k), jnp.array(v), nh, jnp.array(half), 1.0))
    np.testing.assert_allclose(a, b_full, atol=1e-5)
    c = np.asarray(attn_gelu(jnp.array(q), jnp.array(k), jnp.array(v), jnp.array(full), nh, 1.0, block_q=8, block_k=8))
    assert np.abs(a[8:] - c[8:]).max() > 0


# ---------------------------------------------------------------------------
# VMEM estimators (§Perf structural profiling)
# ---------------------------------------------------------------------------


def test_vmem_footprints_monotone_and_sane():
    assert vq_vmem(128, 128, 2, 64) < vq_vmem(256, 128, 2, 64)
    # Mini-scale tiles fit a 16 MiB TPU VMEM comfortably.
    assert vq_vmem(128, 128, 2, 64) < 16 * 1024 * 1024
    assert attn_vmem(128, 128, 128) < 16 * 1024 * 1024
    assert attn_vmem(128, 256, 128) > attn_vmem(128, 128, 128)


def test_gelu_matches_rust_constants():
    # Anchor values asserted on the Rust side too (tensor::ops tests).
    x = jnp.array([0.0, 1.0, 10.0, -10.0])
    y = np.asarray(ref.gelu(x))
    assert abs(y[0]) < 1e-7
    assert abs(y[1] - 0.841192) < 1e-4
    assert abs(y[2] - 10.0) < 1e-4
    assert abs(y[3]) < 1e-4
